//! The paper's three evaluation workloads as [`JobPlan`] builders, plus
//! synthetic data generators for the real-execution mode.
//!
//! * **WordCount** (Secs. 5–6) — a two-stage job: a CPU-heavy map over the
//!   HDFS input, then a small shuffle+reduce. Load-balancing quality is
//!   read off the map stage.
//! * **K-Means** (Sec. 7) — `iterations` repetitions of a simple two-stage
//!   job; the input is read from HDFS once and cached on executors, so
//!   iterations 2+ are pure compute. The partition chosen for iteration 1
//!   *fixes* the per-executor cache, which is exactly why HeMT must get
//!   the weights right up front.
//! * **PageRank** (Sec. 7) — one job of `1 + iterations` stages chained by
//!   shuffles; stages are short, making relative scheduling overhead the
//!   dominant microtasking cost (the paper's Fig. 18 observation).

pub mod gen;

use crate::coordinator::{JobPlan, PartitionPolicy, StageInput, StagePlan};
use crate::hdfs::HdfsFile;

const MB: f64 = (1u64 << 20) as f64;

/// WordCount shape constants: map emits ~5% of its input as (word, count)
/// pairs; the reduce is ~10x lighter per byte than the map.
pub const WC_OUTPUT_RATIO: f64 = 0.05;
pub const WC_REDUCE_CPU_FRACTION: f64 = 0.1;

/// Build the two-stage WordCount job.
pub fn wordcount_job(
    file: HdfsFile,
    map_policy: PartitionPolicy,
    reduce_policy: PartitionPolicy,
    cpu_secs_per_mb: f64,
) -> JobPlan {
    let cpb = cpu_secs_per_mb / MB;
    JobPlan {
        name: "wordcount".into(),
        stages: vec![
            StagePlan {
                input: StageInput::Hdfs { file },
                policy: map_policy,
                cpu_secs_per_byte: cpb,
                output_ratio: WC_OUTPUT_RATIO,
            },
            StagePlan {
                input: StageInput::Shuffle,
                policy: reduce_policy,
                cpu_secs_per_byte: cpb * WC_REDUCE_CPU_FRACTION,
                output_ratio: 0.0,
            },
        ],
    }
}

/// K-Means: the first iteration's job (reads HDFS, caches the partition).
pub fn kmeans_first_job(
    file: HdfsFile,
    map_policy: PartitionPolicy,
    cpu_secs_per_mb: f64,
) -> JobPlan {
    let cpb = cpu_secs_per_mb / MB;
    JobPlan {
        name: "kmeans-iter0".into(),
        stages: vec![
            StagePlan {
                input: StageInput::Hdfs { file },
                policy: map_policy,
                cpu_secs_per_byte: cpb,
                // Map emits per-cluster partial sums: tiny.
                output_ratio: 0.001,
            },
            kmeans_reduce(cpb),
        ],
    }
}

/// K-Means: an iteration over executor-cached data. `partitions` is the
/// `(bytes, executor)` layout fixed by the first iteration's map stage —
/// derive it with [`cached_partitions_of`].
pub fn kmeans_cached_job(partitions: Vec<(u64, usize)>, cpu_secs_per_mb: f64) -> JobPlan {
    let cpb = cpu_secs_per_mb / MB;
    JobPlan {
        name: "kmeans-iter".into(),
        stages: vec![
            StagePlan {
                input: StageInput::Cached { partitions },
                policy: PartitionPolicy::EvenTasks(1), // ignored for cached
                cpu_secs_per_byte: cpb,
                output_ratio: 0.001,
            },
            kmeans_reduce(cpb),
        ],
    }
}

/// The cache layout a map stage leaves behind: one `(bytes, executor)`
/// partition per map task, pinned where it ran.
pub fn cached_partitions_of(stage: &crate::metrics::StageRecord) -> Vec<(u64, usize)> {
    stage.tasks.iter().map(|t| (t.bytes, t.executor)).collect()
}

fn kmeans_reduce(cpb: f64) -> StagePlan {
    StagePlan {
        input: StageInput::Shuffle,
        // Centroid update is a single small aggregation task.
        policy: PartitionPolicy::EvenTasks(1),
        cpu_secs_per_byte: cpb * 0.1,
        output_ratio: 0.0,
    }
}

/// PageRank: one job with an HDFS-read stage followed by `iterations`
/// shuffle-chained rank-update stages. `policy` applies to every stage
/// (for HeMT it must carry one weight per executor; the skewed hash
/// partitioner of Algorithm 1 then shapes every shuffle).
pub fn pagerank_job(
    file: HdfsFile,
    policy: PartitionPolicy,
    iterations: usize,
    cpu_secs_per_mb: f64,
) -> JobPlan {
    let cpb = cpu_secs_per_mb / MB;
    let mut stages = vec![StagePlan {
        input: StageInput::Hdfs { file },
        policy: policy.clone(),
        cpu_secs_per_byte: cpb,
        // Ranks + adjacency flow to every subsequent iteration.
        output_ratio: 1.0,
    }];
    for i in 0..iterations {
        stages.push(StagePlan {
            input: StageInput::Shuffle,
            policy: policy.clone(),
            cpu_secs_per_byte: cpb,
            output_ratio: if i + 1 == iterations { 0.0 } else { 1.0 },
        });
    }
    JobPlan { name: "pagerank".into(), stages }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::driver::{SessionBuilder, SimParams};
    use crate::nodes::Node;

    const MBU: u64 = 1 << 20;

    fn session() -> crate::coordinator::driver::Session {
        SessionBuilder::two_node(Node::fixed("a", 1.0), 1.0, Node::fixed("b", 1.0), 0.4)
            .with_params(SimParams { sched_overhead: 0.0, launch_latency: 0.0, io_setup: 0.0, ..Default::default() })
            .with_hdfs_uplink_bps(1e12)
            .build()
    }

    #[test]
    fn wordcount_has_map_and_reduce() {
        let mut s = session();
        let file = s.hdfs.upload(100 * MBU, 100 * MBU, &mut s.rng);
        let job = wordcount_job(
            file,
            PartitionPolicy::Hemt(vec![1.0, 0.4]),
            PartitionPolicy::Hemt(vec![1.0, 0.4]),
            1.0,
        );
        let rec = s.run_job(&job);
        assert_eq!(rec.stages.len(), 2);
        // Map dominates: reduce moves 5% of the data at 10% intensity.
        assert!(rec.stages[1].completion_time() < 0.1 * rec.stages[0].completion_time());
    }

    #[test]
    fn kmeans_cached_iterations_are_cheaper_than_first() {
        let mut s = session();
        let file = s.hdfs.upload(256 * MBU, 128 * MBU, &mut s.rng);
        let first = s.run_job(&kmeans_first_job(
            file,
            PartitionPolicy::Hemt(vec![1.0, 0.4]),
            1.0,
        ));
        let parts = cached_partitions_of(&first.stages[0]);
        let cached_bytes = first.stages[0].executor_bytes(2);
        let iter = s.run_job(&kmeans_cached_job(parts, 1.0));
        // Cached iteration compute equals the first iteration's, but there
        // is no HDFS read; with ample bandwidth they're comparable, and
        // the cache split must match the HeMT partition.
        assert_eq!(cached_bytes.iter().sum::<u64>(), 256 * MBU);
        assert!((cached_bytes[0] as f64 / (256.0 * MBU as f64) - 1.0 / 1.4).abs() < 0.01);
        assert!(iter.completion_time() <= first.completion_time() + 1.0);
    }

    #[test]
    fn pagerank_stage_count_matches_iterations() {
        let mut s = session();
        let file = s.hdfs.upload(64 * MBU, 64 * MBU, &mut s.rng);
        let job = pagerank_job(file, PartitionPolicy::EvenTasks(2), 5, 0.1);
        let rec = s.run_job(&job);
        assert_eq!(rec.stages.len(), 6);
        // Every iteration re-shuffles the full volume.
        for st in &rec.stages[1..] {
            let total: u64 = st.tasks.iter().map(|t| t.bytes).sum();
            assert!((total as f64 - 64.0 * MB).abs() < MB, "shuffle lost volume: {total}");
        }
    }

    #[test]
    fn pagerank_hemt_skews_every_stage() {
        let mut s = session();
        let file = s.hdfs.upload(64 * MBU, 64 * MBU, &mut s.rng);
        let job = pagerank_job(file, PartitionPolicy::Hemt(vec![1.0, 0.4]), 3, 0.1);
        let rec = s.run_job(&job);
        for st in &rec.stages {
            let by_exec = st.executor_bytes(2);
            let frac = by_exec[0] as f64 / (by_exec[0] + by_exec[1]) as f64;
            assert!((frac - 1.0 / 1.4).abs() < 0.02, "stage skew {frac}");
        }
    }
}

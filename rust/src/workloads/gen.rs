//! Synthetic dataset generators for the real-execution mode.
//!
//! The paper drives its workloads with text corpora (WordCount), point
//! sets (K-Means), and web graphs (PageRank). Real traces aren't available
//! offline, so these generators produce statistically analogous data:
//! Zipf-distributed token streams, Gaussian-mixture points, and random
//! column-stochastic transition matrices — each shaped to the AOT artifact
//! shapes in [`crate::runtime::shapes`].

use crate::util::Rng;

/// Zipf-distributed token ids in `[0, vocab)` — word frequencies in text
/// are famously Zipfian, which is what makes WordCount's reduce skewed.
pub fn zipf_tokens(n: usize, vocab: usize, exponent: f64, rng: &mut Rng) -> Vec<i32> {
    assert!(vocab >= 1);
    // Inverse-CDF sampling over precomputed Zipf weights.
    let weights: Vec<f64> = (1..=vocab).map(|k| 1.0 / (k as f64).powf(exponent)).collect();
    let total: f64 = weights.iter().sum();
    let mut cdf = Vec::with_capacity(vocab);
    let mut acc = 0.0;
    for w in &weights {
        acc += w / total;
        cdf.push(acc);
    }
    (0..n)
        .map(|_| {
            let u = rng.f64();
            match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
                Ok(i) | Err(i) => (i.min(vocab - 1)) as i32,
            }
        })
        .collect()
}

/// Points drawn from `k` spherical Gaussian blobs in `dim` dimensions
/// (blob centers on a scaled hypercube diagonal pattern), row-major.
pub fn gaussian_blobs(n: usize, dim: usize, k: usize, rng: &mut Rng) -> Vec<f32> {
    assert!(k >= 1);
    let centers: Vec<Vec<f64>> = (0..k)
        .map(|c| {
            (0..dim)
                .map(|d| 10.0 * (((c * dim + d) % 7) as f64 - 3.0))
                .collect()
        })
        .collect();
    let mut out = Vec::with_capacity(n * dim);
    for i in 0..n {
        let c = &centers[i % k];
        for d in 0..dim {
            out.push((c[d] + rng.normal()) as f32);
        }
    }
    out
}

/// A random column-stochastic transition matrix (n x n, row-major):
/// each column j has `out_degree` random outgoing links of equal weight
/// (a random graph's PageRank transition matrix, dangling-free).
pub fn transition_matrix(n: usize, out_degree: usize, rng: &mut Rng) -> Vec<f32> {
    assert!(out_degree >= 1 && out_degree <= n);
    let mut m = vec![0.0f32; n * n];
    for col in 0..n {
        let targets = rng.subset(n, out_degree);
        let w = 1.0 / out_degree as f32;
        for &row in &targets {
            m[row * n + col] = w;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_tokens_in_range_and_skewed() {
        let mut rng = Rng::new(1);
        let toks = zipf_tokens(50_000, 100, 1.0, &mut rng);
        assert!(toks.iter().all(|&t| (0..100).contains(&t)));
        let mut counts = vec![0usize; 100];
        for &t in &toks {
            counts[t as usize] += 1;
        }
        // Zipf: rank-1 token much more frequent than rank-50.
        assert!(counts[0] > 5 * counts[49], "{} vs {}", counts[0], counts[49]);
    }

    #[test]
    fn blobs_have_centers_apart() {
        let mut rng = Rng::new(2);
        let pts = gaussian_blobs(1_000, 8, 2, &mut rng);
        assert_eq!(pts.len(), 8_000);
        // Means of alternating points differ (two blobs).
        let mean = |start: usize| -> f64 {
            (start..1_000)
                .step_by(2)
                .map(|i| pts[i * 8] as f64)
                .sum::<f64>()
                / 500.0
        };
        assert!((mean(0) - mean(1)).abs() > 1.0);
    }

    #[test]
    fn transition_matrix_is_column_stochastic() {
        let mut rng = Rng::new(3);
        let n = 64;
        let m = transition_matrix(n, 8, &mut rng);
        for col in 0..n {
            let s: f32 = (0..n).map(|row| m[row * n + col]).sum();
            assert!((s - 1.0).abs() < 1e-5, "col {col} sums to {s}");
        }
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let a = zipf_tokens(100, 50, 1.0, &mut Rng::new(9));
        let b = zipf_tokens(100, 50, 1.0, &mut Rng::new(9));
        assert_eq!(a, b);
    }
}

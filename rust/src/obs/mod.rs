//! Run telemetry: span tracing, simulator self-profiling, and export.
//!
//! The paper's argument is an *overhead* argument — HeMT wins exactly
//! where HomT's scheduling and I/O overheads dominate — so this module
//! makes where-time-goes observable end to end:
//!
//! * **Span recording.** A [`Recorder`], installed per thread via
//!   [`install`], passively collects per-task spans (dispatched →
//!   input-read → compute → finished, with executor attribution) and
//!   instant events (steal decisions, capacity/link dynamics events,
//!   netsim re-solves, OA re-partition rounds) as the drivers run. The
//!   recorder is strictly passive: hooks fire through [`record`], which
//!   is a no-op unless a recorder is installed on the *current* thread,
//!   and no hook draws from any RNG or mutates simulation state — every
//!   golden is bit-identical with tracing on or off.
//! * **Export.** [`chrome_trace`] renders a recording as Chrome
//!   trace-event JSON (load in Perfetto / `chrome://tracing`);
//!   [`breakdown`] prints the paper's Fig-2-style per-stage
//!   decomposition (compute / overhead / idle fractions per policy
//!   arm). Both are driven by `hemt trace <request.json> --out t.json`.
//! * **Self-profiling.** Always-on process-global counters and
//!   hand-rolled log-bucket histograms ([`LogHist`]) aggregate engine
//!   heap traffic, per-node re-levellings, incremental-vs-full netsim
//!   solves and task/stage timings across every run in the process —
//!   surfaced by `GET /metrics` in Prometheus text exposition format
//!   (see [`prometheus_text`]).
//!
//! Because recording is keyed on a thread-local, a multi-threaded sweep
//! records only the units that execute on the installing thread; trace
//! export therefore runs on a serial runner
//! ([`crate::api::execute_traced`]), where the recording order *is* the
//! deterministic sim-time order.

use crate::util::json::{self, Value};
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

// ------------------------------------------------------------- recorder

/// One observed task of a stage: the driver's recorded lifecycle
/// timestamps plus the input-drain instant the stage loop noted.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskObs {
    pub task: usize,
    pub executor: usize,
    pub bytes: u64,
    pub dispatched: f64,
    pub started: f64,
    /// When the task's input stream drained (`None`: no network input —
    /// cached stages and CPU-carve stolen tasks). In the fluid model
    /// compute overlaps the read; `finished - input_done` is the
    /// pure-CPU tail (exactly the stealing driver's victim criterion).
    pub input_done: Option<f64>,
    pub finished: f64,
    /// Appended mid-stage by a steal (CPU carve or stream re-issue).
    pub stolen: bool,
}

/// One completed stage: boundary times, the executor slot count, and
/// every task observed in it.
#[derive(Debug, Clone, PartialEq)]
pub struct StageObs {
    pub start: f64,
    pub end: f64,
    /// Total executor slots (the idle-time denominator).
    pub slots: usize,
    pub tasks: Vec<TaskObs>,
}

impl StageObs {
    /// The Fig-2 decomposition in seconds: `(overhead, busy, idle)`
    /// against `slots x (end - start)` slot-seconds. Overhead is
    /// dispatch→launch (scheduler serialization + launch latency + I/O
    /// setup), busy is launch→finish, idle is the remainder (clamped:
    /// a speculative duplicate holds a second slot the task records
    /// don't itemize).
    pub fn decompose(&self) -> (f64, f64, f64) {
        let total = self.slots as f64 * (self.end - self.start);
        let overhead: f64 = self.tasks.iter().map(|t| (t.started - t.dispatched).max(0.0)).sum();
        let busy: f64 = self.tasks.iter().map(|t| (t.finished - t.started).max(0.0)).sum();
        (overhead, busy, (total - overhead - busy).max(0.0))
    }

    pub fn completion_time(&self) -> f64 {
        self.end - self.start
    }
}

/// Everything a [`Recorder`] collects, in recording order (deterministic
/// sim-time order on a serial runner).
#[derive(Debug, Clone, PartialEq)]
pub enum ObsEvent {
    /// A new output (figure / comparison) began — from the trace entry
    /// point, not the sim.
    Output { index: usize, name: String },
    /// A sweep work unit began on this thread.
    Unit { index: usize, label: String },
    /// A stage completed.
    Stage(StageObs),
    /// A successful steal: `task` is the carved task appended to the
    /// stage, `victim` the task it was carved from.
    Steal { t: f64, victim: usize, task: usize, thief_exec: usize, work: f64, stream: bool },
    /// A node capacity-dynamics event applied mid-run.
    Capacity { t: f64, node: usize, mult: f64 },
    /// A link capacity-dynamics event applied mid-run.
    LinkCapacity { t: f64, link: usize, mult: f64 },
    /// The network engine re-solved rates (incremental or full).
    NetSolve { t: f64, incremental: bool, flows: u64 },
    /// A closed-loop driver re-partitioned between rounds.
    OaRound { t: f64, driver: &'static str, round: usize },
}

/// A passive span/event recorder. Install with [`install`], feed through
/// [`record`], retrieve with [`take`].
#[derive(Debug, Default)]
pub struct Recorder {
    pub events: Vec<ObsEvent>,
    /// Per-stage scratch: first-attempt input-drain instants by task
    /// index, consumed when the stage closes.
    input_done: HashMap<usize, f64>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    pub fn begin_output(&mut self, index: usize, name: &str) {
        self.events.push(ObsEvent::Output { index, name: name.to_string() });
    }

    pub fn begin_unit(&mut self, index: usize) {
        self.events.push(ObsEvent::Unit { index, label: String::new() });
    }

    /// Attach the label (policy arm / cell name) to the most recent
    /// unit marker — known only once the unit's samples exist.
    pub fn label_unit(&mut self, label: &str) {
        if let Some(ObsEvent::Unit { label: l, .. }) =
            self.events.iter_mut().rev().find(|e| matches!(e, ObsEvent::Unit { .. }))
        {
            if l.is_empty() {
                *l = label.to_string();
            }
        }
    }

    /// The stage loop noted task `i`'s (first-attempt) input stream
    /// draining at `t`.
    pub fn note_input_done(&mut self, task: usize, t: f64) {
        self.input_done.entry(task).or_insert(t);
    }

    pub fn input_done_of(&self, task: usize) -> Option<f64> {
        self.input_done.get(&task).copied()
    }

    /// Close a stage: record it and clear the per-stage scratch.
    pub fn end_stage(&mut self, stage: StageObs) {
        self.input_done.clear();
        self.events.push(ObsEvent::Stage(stage));
    }

    pub fn push(&mut self, ev: ObsEvent) {
        self.events.push(ev);
    }

    /// Drain the events collected so far (streaming export — the serve
    /// layer's per-unit `span` SSE frames).
    pub fn drain_events(&mut self) -> Vec<ObsEvent> {
        self.input_done.clear();
        std::mem::take(&mut self.events)
    }

    pub fn stages(&self) -> impl Iterator<Item = &StageObs> {
        self.events.iter().filter_map(|e| match e {
            ObsEvent::Stage(s) => Some(s),
            _ => None,
        })
    }
}

thread_local! {
    static OBS_ACTIVE: Cell<bool> = const { Cell::new(false) };
    static OBS_RECORDER: RefCell<Option<Recorder>> = const { RefCell::new(None) };
}

/// Install a recorder on the current thread (replacing any previous
/// one). Hooks on this thread start collecting; other threads are
/// untouched.
pub fn install(r: Recorder) {
    OBS_RECORDER.with(|c| *c.borrow_mut() = Some(r));
    OBS_ACTIVE.with(|a| a.set(true));
}

/// Remove and return the current thread's recorder (hooks go back to
/// no-ops).
pub fn take() -> Option<Recorder> {
    OBS_ACTIVE.with(|a| a.set(false));
    OBS_RECORDER.with(|c| c.borrow_mut().take())
}

/// Whether a recorder is installed on this thread. One thread-local
/// `Cell` read — the hot-path guard the engine uses per step.
#[inline]
pub fn active() -> bool {
    OBS_ACTIVE.with(|a| a.get())
}

/// Run `f` against the installed recorder, if any. The closure must be
/// passive: read simulation state, never mutate it, never touch an RNG.
#[inline]
pub fn record<F: FnOnce(&mut Recorder)>(f: F) {
    if !active() {
        return;
    }
    OBS_RECORDER.with(|c| {
        if let Some(r) = c.borrow_mut().as_mut() {
            f(r);
        }
    });
}

// ------------------------------------------------- log-bucket histogram

/// Number of power-of-two buckets (covers 1 µs .. ~36 000 s and change).
pub const HIST_BUCKETS: usize = 48;

/// A hand-rolled log-bucket histogram over non-negative durations in
/// seconds. Bucket `i` counts observations with `value <= 2^i µs`
/// (bucket 0: `<= 1 µs`); the last bucket absorbs the tail. No floats
/// are stored beyond the running sum, no allocation after construction.
#[derive(Debug, Clone)]
pub struct LogHist {
    pub counts: [u64; HIST_BUCKETS],
    pub count: u64,
    pub sum: f64,
}

impl Default for LogHist {
    fn default() -> LogHist {
        LogHist { counts: [0; HIST_BUCKETS], count: 0, sum: 0.0 }
    }
}

impl LogHist {
    pub fn new() -> LogHist {
        LogHist::default()
    }

    pub fn observe(&mut self, seconds: f64) {
        let v = seconds.max(0.0);
        let micros = (v * 1e6).ceil() as u64;
        // ceil(log2(micros)) without floats; micros <= 1 lands in 0.
        let bucket = if micros <= 1 {
            0
        } else {
            (64 - (micros - 1).leading_zeros() as usize).min(HIST_BUCKETS - 1)
        };
        self.counts[bucket] += 1;
        self.count += 1;
        self.sum += v;
    }

    /// Upper bound of bucket `i`, in seconds.
    pub fn bound(i: usize) -> f64 {
        (1u64 << i) as f64 * 1e-6
    }

    /// Append this histogram in Prometheus text exposition format
    /// (cumulative `_bucket{le=...}` lines plus `_sum` / `_count`).
    fn prometheus_into(&self, out: &mut String, name: &str) {
        out.push_str(&format!("# TYPE {name} histogram\n"));
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            // The last bucket is the +Inf catch-all.
            if i == HIST_BUCKETS - 1 {
                out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n"));
            } else {
                out.push_str(&format!(
                    "{name}_bucket{{le=\"{}\"}} {cum}\n",
                    fmt_f64(Self::bound(i))
                ));
            }
        }
        out.push_str(&format!("{name}_sum {}\n", fmt_f64(self.sum)));
        out.push_str(&format!("{name}_count {}\n", self.count));
    }
}

fn fmt_f64(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

// ------------------------------------------- process-global self-profile

/// Always-on process-global counters, fed by the sim engine and drivers
/// regardless of whether a recorder is installed (plain relaxed atomic
/// adds — nothing here can perturb a run). `GET /metrics` surfaces them.
#[derive(Debug, Default)]
pub struct GlobalStats {
    /// Jobs driven to completion ([`crate::coordinator::driver`]).
    pub jobs_run: AtomicU64,
    pub stages_run: AtomicU64,
    pub tasks_finished: AtomicU64,
    pub steals: AtomicU64,
    /// Engine self-profile deltas absorbed at job end.
    pub engine_steps: AtomicU64,
    pub engine_heap_pushes: AtomicU64,
    pub engine_heap_pops: AtomicU64,
    pub engine_heap_compactions: AtomicU64,
    pub engine_node_relevels: AtomicU64,
    pub engine_timers_set: AtomicU64,
    pub netsim_incremental_solves: AtomicU64,
    pub netsim_full_solves: AtomicU64,
    pub netsim_flows_relevelled: AtomicU64,
    /// Real-execution bridge ([`crate::runtime`]).
    pub runtime_executes: AtomicU64,
    hists: Mutex<GlobalHists>,
}

#[derive(Debug, Default)]
struct GlobalHists {
    /// Per-task launch→finish duration (sim seconds).
    task_duration: LogHist,
    /// Per-task dispatch→launch overhead (sim seconds).
    task_overhead: LogHist,
    /// Per-stage completion time (sim seconds).
    stage_completion: LogHist,
    /// PJRT artifact execution wall time (real seconds).
    runtime_execute_wall: LogHist,
}

impl GlobalStats {
    /// Absorb one finished job: engine/netsim profile deltas plus
    /// per-task and per-stage timing observations.
    pub fn absorb_job(
        &self,
        engine_delta: &crate::sim::EngineProfile,
        net_delta: &crate::netsim::SolveStats,
        stages: &[crate::metrics::StageRecord],
    ) {
        let add = |c: &AtomicU64, v: u64| {
            c.fetch_add(v, Ordering::Relaxed);
        };
        add(&self.jobs_run, 1);
        add(&self.stages_run, stages.len() as u64);
        add(
            &self.tasks_finished,
            stages.iter().map(|s| s.tasks.len() as u64).sum(),
        );
        add(&self.engine_steps, engine_delta.steps);
        add(&self.engine_heap_pushes, engine_delta.heap_pushes);
        add(&self.engine_heap_pops, engine_delta.heap_pops);
        add(&self.engine_heap_compactions, engine_delta.heap_compactions);
        add(&self.engine_node_relevels, engine_delta.node_relevels);
        add(&self.engine_timers_set, engine_delta.timers_set);
        add(&self.netsim_incremental_solves, net_delta.incremental_solves);
        add(&self.netsim_full_solves, net_delta.full_solves);
        add(&self.netsim_flows_relevelled, net_delta.flows_relevelled);
        let mut h = self.hists.lock().unwrap();
        for st in stages {
            h.stage_completion.observe(st.completion_time());
            for t in &st.tasks {
                h.task_duration.observe((t.finished - t.started).max(0.0));
                h.task_overhead.observe((t.started - t.dispatched).max(0.0));
            }
        }
    }

    pub fn note_steal(&self) {
        self.steals.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_runtime_execute(&self, wall_seconds: f64) {
        self.runtime_executes.fetch_add(1, Ordering::Relaxed);
        self.hists.lock().unwrap().runtime_execute_wall.observe(wall_seconds);
    }
}

/// The process-global self-profile.
pub fn global() -> &'static GlobalStats {
    static GLOBAL: OnceLock<GlobalStats> = OnceLock::new();
    GLOBAL.get_or_init(GlobalStats::default)
}

/// Render the global self-profile plus caller-supplied gauges/counters
/// (the serve layer's request/memo/queue numbers) in Prometheus text
/// exposition format. Counter names get the `hemt_` prefix here; pass
/// bare names in `extra`.
pub fn prometheus_text(extra: &[(&str, u64)]) -> String {
    let g = global();
    let mut out = String::new();
    let mut counter = |name: &str, v: u64| {
        out.push_str(&format!("# TYPE hemt_{name} counter\nhemt_{name} {v}\n"));
    };
    for (name, v) in extra {
        counter(name, *v);
    }
    let load = |c: &AtomicU64| c.load(Ordering::Relaxed);
    counter("jobs_run_total", load(&g.jobs_run));
    counter("stages_run_total", load(&g.stages_run));
    counter("tasks_finished_total", load(&g.tasks_finished));
    counter("steals_total", load(&g.steals));
    counter("engine_steps_total", load(&g.engine_steps));
    counter("engine_heap_pushes_total", load(&g.engine_heap_pushes));
    counter("engine_heap_pops_total", load(&g.engine_heap_pops));
    counter("engine_heap_compactions_total", load(&g.engine_heap_compactions));
    counter("engine_node_relevels_total", load(&g.engine_node_relevels));
    counter("engine_timers_set_total", load(&g.engine_timers_set));
    counter("netsim_incremental_solves_total", load(&g.netsim_incremental_solves));
    counter("netsim_full_solves_total", load(&g.netsim_full_solves));
    counter("netsim_flows_relevelled_total", load(&g.netsim_flows_relevelled));
    counter("runtime_executes_total", load(&g.runtime_executes));
    let h = g.hists.lock().unwrap();
    h.task_duration.prometheus_into(&mut out, "hemt_task_duration_seconds");
    h.task_overhead.prometheus_into(&mut out, "hemt_task_overhead_seconds");
    h.stage_completion.prometheus_into(&mut out, "hemt_stage_completion_seconds");
    h.runtime_execute_wall.prometheus_into(&mut out, "hemt_runtime_execute_wall_seconds");
    out
}

// --------------------------------------------------- chrome trace export

const US: f64 = 1e6;

fn x_event(pid: usize, tid: usize, name: &str, cat: &str, ts: f64, dur: f64, args: Value) -> Value {
    json::obj(vec![
        ("args", args),
        ("cat", json::s(cat)),
        ("dur", json::num((dur * US).max(0.0))),
        ("name", json::s(name)),
        ("ph", json::s("X")),
        ("pid", json::num(pid as f64)),
        ("tid", json::num(tid as f64)),
        ("ts", json::num(ts * US)),
    ])
}

fn i_event(pid: usize, tid: usize, name: &str, cat: &str, ts: f64, args: Value) -> Value {
    json::obj(vec![
        ("args", args),
        ("cat", json::s(cat)),
        ("name", json::s(name)),
        ("ph", json::s("i")),
        ("pid", json::num(pid as f64)),
        ("s", json::s("t")),
        ("tid", json::num(tid as f64)),
        ("ts", json::num(ts * US)),
    ])
}

fn meta_event(pid: usize, tid: Option<usize>, which: &str, name: &str) -> Value {
    let mut pairs = vec![
        ("args", json::obj(vec![("name", json::s(name))])),
        ("name", json::s(which)),
        ("ph", json::s("M")),
        ("pid", json::num(pid as f64)),
    ];
    if let Some(t) = tid {
        pairs.push(("tid", json::num(t as f64)));
    }
    json::obj(pairs)
}

/// Executors live on tids 1..; tid 0 is the driver lane (stage spans and
/// instant events).
const DRIVER_TID: usize = 0;

/// Render a flat event slice as Chrome trace events under one pid. Used
/// directly for the serve layer's per-unit `span` frames; the full-file
/// export ([`chrome_trace`]) adds pid assignment and metadata.
pub fn chrome_events(events: &[ObsEvent], pid: usize) -> Vec<Value> {
    let mut out = Vec::new();
    let mut stage_no = 0usize;
    for ev in events {
        match ev {
            ObsEvent::Output { .. } | ObsEvent::Unit { .. } => {}
            ObsEvent::Stage(s) => {
                out.push(x_event(
                    pid,
                    DRIVER_TID,
                    &format!("stage {stage_no}"),
                    "stage",
                    s.start,
                    s.end - s.start,
                    json::obj(vec![
                        ("slots", json::num(s.slots as f64)),
                        ("tasks", json::num(s.tasks.len() as f64)),
                    ]),
                ));
                stage_no += 1;
                for t in &s.tasks {
                    let tid = t.executor + 1;
                    let args = json::obj(vec![
                        ("bytes", json::num(t.bytes as f64)),
                        ("executor", json::num(t.executor as f64)),
                        ("stolen", json::num(if t.stolen { 1.0 } else { 0.0 })),
                    ]);
                    out.push(x_event(
                        pid,
                        tid,
                        &format!("task {}", t.task),
                        "task",
                        t.dispatched,
                        t.finished - t.dispatched,
                        args,
                    ));
                    if t.started > t.dispatched {
                        out.push(x_event(
                            pid,
                            tid,
                            "overhead",
                            "phase",
                            t.dispatched,
                            t.started - t.dispatched,
                            json::obj(vec![]),
                        ));
                    }
                    // In the fluid model compute overlaps the input
                    // read; the trace shows "input" up to the stream
                    // drain and "compute" as the pure-CPU tail, which
                    // together tile launch→finish.
                    let compute_from = match t.input_done {
                        Some(d) if d > t.started => {
                            out.push(x_event(
                                pid,
                                tid,
                                "input",
                                "phase",
                                t.started,
                                (d - t.started).min(t.finished - t.started),
                                json::obj(vec![]),
                            ));
                            d.min(t.finished)
                        }
                        _ => t.started,
                    };
                    if t.finished > compute_from {
                        out.push(x_event(
                            pid,
                            tid,
                            "compute",
                            "phase",
                            compute_from,
                            t.finished - compute_from,
                            json::obj(vec![]),
                        ));
                    }
                }
            }
            ObsEvent::Steal { t, victim, task, thief_exec, work, stream } => {
                out.push(i_event(
                    pid,
                    thief_exec + 1,
                    "steal",
                    "steal",
                    *t,
                    json::obj(vec![
                        ("stream", json::num(if *stream { 1.0 } else { 0.0 })),
                        ("task", json::num(*task as f64)),
                        ("victim", json::num(*victim as f64)),
                        ("work", json::num(*work)),
                    ]),
                ));
            }
            ObsEvent::Capacity { t, node, mult } => {
                out.push(i_event(
                    pid,
                    DRIVER_TID,
                    "capacity",
                    "dynamics",
                    *t,
                    json::obj(vec![
                        ("mult", json::num(*mult)),
                        ("node", json::num(*node as f64)),
                    ]),
                ));
            }
            ObsEvent::LinkCapacity { t, link, mult } => {
                out.push(i_event(
                    pid,
                    DRIVER_TID,
                    "link_capacity",
                    "dynamics",
                    *t,
                    json::obj(vec![
                        ("link", json::num(*link as f64)),
                        ("mult", json::num(*mult)),
                    ]),
                ));
            }
            ObsEvent::NetSolve { t, incremental, flows } => {
                out.push(i_event(
                    pid,
                    DRIVER_TID,
                    "net_solve",
                    "netsim",
                    *t,
                    json::obj(vec![
                        ("flows", json::num(*flows as f64)),
                        ("incremental", json::num(if *incremental { 1.0 } else { 0.0 })),
                    ]),
                ));
            }
            ObsEvent::OaRound { t, driver, round } => {
                out.push(i_event(
                    pid,
                    DRIVER_TID,
                    "oa_round",
                    "driver",
                    *t,
                    json::obj(vec![
                        ("driver", json::s(driver)),
                        ("round", json::num(*round as f64)),
                    ]),
                ));
            }
        }
    }
    out
}

/// Emit one slice (the events of one sweep unit) under its own pid:
/// metadata events naming the process/threads, then the rendered slice.
/// Empty slices are dropped without consuming a pid.
fn emit_slice(events: &mut Vec<Value>, pid: &mut usize, name: &str, slice: &mut Vec<ObsEvent>) {
    if slice.is_empty() {
        return;
    }
    let mut execs: Vec<usize> = slice
        .iter()
        .filter_map(|e| match e {
            ObsEvent::Stage(s) => Some(s.tasks.iter().map(|t| t.executor)),
            _ => None,
        })
        .flatten()
        .collect();
    execs.sort_unstable();
    execs.dedup();
    events.push(meta_event(*pid, None, "process_name", name));
    events.push(meta_event(*pid, Some(DRIVER_TID), "thread_name", "driver"));
    for &e in &execs {
        events.push(meta_event(*pid, Some(e + 1), "thread_name", &format!("exec {e}")));
    }
    events.extend(chrome_events(slice, *pid));
    slice.clear();
    *pid += 1;
}

fn slice_name(out_name: &str, unit_label: &Option<String>) -> String {
    let unit = unit_label.as_deref().unwrap_or("run");
    if out_name.is_empty() {
        unit.to_string()
    } else {
        format!("{out_name} / {unit}")
    }
}

/// Render a full recording as a Chrome trace-event JSON document
/// (`{"displayTimeUnit": "ms", "traceEvents": [...]}`). Sim time maps to
/// microseconds 1:1. Each sweep unit becomes its own pid (trials replay
/// overlapping sim-time ranges, so they must not share a timeline);
/// process names carry the output name and unit label, thread names the
/// executor index.
pub fn chrome_trace(rec: &Recorder) -> Value {
    let mut events: Vec<Value> = Vec::new();
    let mut pid = 0usize;
    let mut out_name = String::new();
    let mut unit_label: Option<String> = None;
    let mut slice: Vec<ObsEvent> = Vec::new();
    for ev in &rec.events {
        match ev {
            ObsEvent::Output { name, .. } => {
                emit_slice(&mut events, &mut pid, &slice_name(&out_name, &unit_label), &mut slice);
                out_name = name.clone();
                unit_label = None;
            }
            ObsEvent::Unit { index, label } => {
                emit_slice(&mut events, &mut pid, &slice_name(&out_name, &unit_label), &mut slice);
                unit_label = Some(if label.is_empty() {
                    format!("unit {index}")
                } else {
                    format!("unit {index}: {label}")
                });
            }
            other => slice.push(other.clone()),
        }
    }
    emit_slice(&mut events, &mut pid, &slice_name(&out_name, &unit_label), &mut slice);
    json::obj(vec![
        ("displayTimeUnit", json::s("ms")),
        ("traceEvents", Value::Arr(events)),
    ])
}

// --------------------------------------------------- per-stage breakdown

/// The paper's Fig-2-style text decomposition: one row per recorded
/// stage, grouped by unit (policy arm), with compute / overhead / idle
/// fractions of total slot-seconds plus steal counts.
pub fn breakdown(rec: &Recorder) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<44} {:>9} {:>8} {:>8} {:>8} {:>6} {:>6}\n",
        "unit / stage", "compl (s)", "compute", "ovhd", "idle", "tasks", "steals"
    ));
    let mut unit = String::from("run");
    let mut stage_no = 0usize;
    let mut steals_in_stage = 0usize;
    for ev in &rec.events {
        match ev {
            ObsEvent::Output { name, .. } => {
                unit = name.clone();
                stage_no = 0;
            }
            ObsEvent::Unit { index, label } => {
                unit = if label.is_empty() {
                    format!("unit {index}")
                } else {
                    format!("unit {index}: {label}")
                };
                stage_no = 0;
            }
            ObsEvent::Steal { .. } => steals_in_stage += 1,
            ObsEvent::Stage(s) => {
                let (overhead, busy, idle) = s.decompose();
                let total = (s.slots as f64 * s.completion_time()).max(f64::MIN_POSITIVE);
                out.push_str(&format!(
                    "{:<44} {:>9.3} {:>7.1}% {:>7.1}% {:>7.1}% {:>6} {:>6}\n",
                    format!("{unit} / stage {stage_no}"),
                    s.completion_time(),
                    100.0 * busy / total,
                    100.0 * overhead / total,
                    100.0 * idle / total,
                    s.tasks.len(),
                    steals_in_stage,
                ));
                stage_no += 1;
                steals_in_stage = 0;
            }
            _ => {}
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_is_thread_local_and_removable() {
        assert!(!active());
        record(|_| panic!("must not fire when inactive"));
        install(Recorder::new());
        assert!(active());
        record(|r| r.push(ObsEvent::NetSolve { t: 1.0, incremental: true, flows: 3 }));
        let other = std::thread::spawn(|| active()).join().unwrap();
        assert!(!other, "recorder must not leak across threads");
        let rec = take().unwrap();
        assert_eq!(rec.events.len(), 1);
        assert!(!active());
        assert!(take().is_none());
    }

    #[test]
    fn log_hist_buckets_are_cumulative_and_exact() {
        let mut h = LogHist::new();
        h.observe(0.0); // bucket 0
        h.observe(1e-6); // exactly 1 µs -> bucket 0
        h.observe(3e-6); // bucket 2 (4 µs bound)
        h.observe(1.0); // 1 s = 1e6 µs -> 2^20 bound
        assert_eq!(h.count, 4);
        assert_eq!(h.counts[0], 2);
        assert_eq!(h.counts[2], 1);
        assert_eq!(h.counts[20], 1);
        assert!((h.sum - 1.000004).abs() < 1e-9);
        let mut text = String::new();
        h.prometheus_into(&mut text, "t_seconds");
        assert!(text.starts_with("# TYPE t_seconds histogram\n"));
        assert!(text.contains("t_seconds_bucket{le=\"+Inf\"} 4\n"));
        assert!(text.contains("t_seconds_count 4\n"));
        // Cumulative counts never decrease.
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "{line}");
            last = v;
        }
    }

    #[test]
    fn stage_decomposition_reconciles_with_slot_seconds() {
        let s = StageObs {
            start: 10.0,
            end: 20.0,
            slots: 2,
            tasks: vec![
                TaskObs {
                    task: 0,
                    executor: 0,
                    bytes: 100,
                    dispatched: 10.0,
                    started: 11.0,
                    input_done: Some(13.0),
                    finished: 18.0,
                    stolen: false,
                },
                TaskObs {
                    task: 1,
                    executor: 1,
                    bytes: 100,
                    dispatched: 10.5,
                    started: 11.5,
                    input_done: None,
                    finished: 20.0,
                    stolen: false,
                },
            ],
        };
        let (overhead, busy, idle) = s.decompose();
        assert!((overhead - 2.0).abs() < 1e-12);
        assert!((busy - 15.5).abs() < 1e-12);
        assert!((overhead + busy + idle - 2.0 * 10.0).abs() < 1e-12);
    }

    #[test]
    fn chrome_trace_shapes_are_valid() {
        let mut r = Recorder::new();
        r.begin_output(0, "fig9");
        r.begin_unit(0);
        r.label_unit("homt");
        r.push(ObsEvent::Steal {
            t: 12.0,
            victim: 0,
            task: 2,
            thief_exec: 1,
            work: 3.5,
            stream: false,
        });
        r.end_stage(StageObs {
            start: 10.0,
            end: 20.0,
            slots: 2,
            tasks: vec![TaskObs {
                task: 0,
                executor: 0,
                bytes: 64,
                dispatched: 10.0,
                started: 11.0,
                input_done: Some(12.0),
                finished: 19.0,
                stolen: false,
            }],
        });
        let doc = chrome_trace(&r);
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!evs.is_empty());
        for e in evs {
            let ph = e.get("ph").unwrap().as_str().unwrap();
            assert!(matches!(ph, "X" | "i" | "M"), "{ph}");
            if ph == "X" {
                assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            }
            if ph != "M" {
                assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
            }
        }
        // Round-trips through the in-repo JSON parser.
        let text = doc.compact();
        let parsed = Value::parse(&text).unwrap();
        assert!(parsed.get("traceEvents").is_some());
        let table = breakdown(&r);
        assert!(table.contains("unit 0: homt / stage 0"), "{table}");
        assert!(table.contains("steals"), "{table}");
    }
}

//! Ablation studies for the design choices DESIGN.md calls out — the
//! knobs the paper discusses but does not sweep:
//!
//! * [`alpha`] — the OA-HeMT forgetting factor's responsiveness-vs-jitter
//!   tradeoff (Sec. 5.1's closing discussion).
//! * [`speculation`] — Spark-style speculative execution vs HeMT: when
//!   duplicate-and-race helps (transient stragglers) and when capacity-
//!   aware sizing is strictly better (persistent heterogeneity, Sec. 8).
//! * [`rack_awareness`] — footnote 3: rack-aware placement with a
//!   cluster-local writer concentrates blocks and intensifies uplink
//!   competition.
//! * [`stale_credits`] — footnote 8: CloudWatch's 1–5 minute update lag
//!   degrades credit-based HeMT planning.
//!
//! Like the figures, each ablation is a [`SweepSpec`] (`*_spec()`): the
//! alpha sweep fans its five 70-job adaptation sequences out over the
//! worker pool; speculation/rack fan out per-trial simulations.

use crate::config::{ClusterConfig, NodeConfig, PolicyConfig, WorkloadConfig, WorkloadKind};
use crate::coordinator::driver::{SimParams, Speculation};
use crate::coordinator::PartitionPolicy;
use crate::estimator::credits::{plan, CreditCurve};
use crate::estimator::SpeedEstimator;
use crate::experiments::{default_runner, observe_map_stage, resolve_policy, MB};
use crate::hdfs::Placement;
use crate::metrics::Figure;
use crate::sweep::{Sample, SweepSpec};
use crate::util::Summary;
use crate::workloads;

fn two_full_cores(hdfs_mbps: f64) -> ClusterConfig {
    ClusterConfig {
        nodes: vec![NodeConfig::Static { cores: 1.0 }, NodeConfig::Static { cores: 1.0 }],
        exec_cpus: vec![1.0, 1.0],
        interference: vec![vec![], vec![]],
        node_uplink_mbps: 600.0,
        node_downlink_mbps: 600.0,
        hdfs_datanodes: 4,
        hdfs_replication: 2,
        hdfs_uplink_mbps: hdfs_mbps,
        hdfs_serving_eta: 0.26,
    }
}

/// Forgetting-factor sweep: with noisy per-task difficulty
/// (`exec_noise = 0.3`) and an interference step at job 15, measure the
/// steady-state jitter (σ of settled map times) and the disturbance
/// recovery cost (mean excess over the settled level in the 4 jobs after
/// the hit). Sec. 5.1: small α tracks the latest sample (fast recovery,
/// high jitter); large α averages noise out (smooth, slow recovery).
pub fn alpha_spec() -> SweepSpec {
    let wl = WorkloadConfig {
        kind: WorkloadKind::WordCount,
        data_mb: 512,
        block_mb: 256,
        cpu_secs_per_mb: 42.0 / 1024.0,
        iterations: 1,
    };
    let mut spec = SweepSpec::new(
        "Ablation: OA-HeMT forgetting factor (noise sigma=0.3, interference at job 15)",
        "alpha",
        "seconds",
    );
    let jitter = spec.series("partition instability (share sigma, steady)");
    let recovery = spec.series("recovery cost (mean excess secs, jobs 16-19)");
    for &a in &[0.0, 0.25, 0.5, 0.75, 0.9] {
        let wl = wl.clone();
        // One 70-job adaptation sequence per alpha; the five sequences
        // are independent and run in parallel on the sweep pool.
        spec.sequence(move || {
            let mut params = SimParams::default();
            params.exec_noise = 0.3;
            let cluster = two_full_cores(600.0);
            let mut s = cluster.build_session(params, 7);
            let mut est = SpeedEstimator::new(a);
            let mut times = Vec::new();
            let mut shares = Vec::new();
            for job_idx in 0..70usize {
                if job_idx == 15 {
                    let t = s.engine.now;
                    s.engine.set_node_interference(1, vec![(t, 0.5)]);
                }
                let file = s.hdfs.upload(wl.data_mb * MB, wl.block_mb * MB, &mut s.rng);
                let policy = resolve_policy(
                    &PolicyConfig::HemtAdaptive { alpha: a },
                    &s,
                    if est.is_cold() { None } else { Some(&est) },
                );
                let job = workloads::wordcount_job(
                    file,
                    policy.clone(),
                    policy,
                    wl.cpu_secs_per_mb,
                );
                let rec = s.run_job(&job);
                observe_map_stage(&mut est, &rec, 2);
                times.push(rec.map_stage_time());
                let by_exec = rec.stages[0].executor_bytes(2);
                shares.push(by_exec[1] as f64 / (by_exec[0] + by_exec[1]) as f64);
            }
            // Steady window well past the alpha=0.9 re-convergence
            // horizon. The Sec. 5.1 tradeoff is about the *estimate*: a
            // small alpha chases per-task noise (unstable partitions), a
            // large alpha averages it out but reacts slowly to changes.
            let share_stability = Summary::of(&shares[50..70]);
            let settled = Summary::of(&times[50..70]);
            let excess: Vec<f64> = times[16..20].iter().map(|t| t - settled.mean).collect();
            vec![
                Sample {
                    series: jitter,
                    x: a,
                    label: String::new(),
                    value: share_stability.std,
                },
                Sample {
                    series: recovery,
                    x: a,
                    label: String::new(),
                    value: excess.iter().sum::<f64>() / excess.len() as f64,
                },
            ]
        });
    }
    spec
}

pub fn alpha() -> Figure {
    default_runner().run(&alpha_spec())
}

/// One speculation-ablation trial: a WordCount map stage under the given
/// cluster/policy with speculation on or off.
fn speculation_trial(
    cluster: &ClusterConfig,
    wl: &WorkloadConfig,
    policy: &PolicyConfig,
    speculation: Option<Speculation>,
    seed: u64,
) -> f64 {
    let mut params = SimParams::default();
    params.speculation = speculation;
    let mut s = cluster.build_session(params, seed);
    let file = s.hdfs.upload(wl.data_mb * MB, wl.block_mb * MB, &mut s.rng);
    let map = resolve_policy(policy, &s, None);
    let job = workloads::wordcount_job(
        file,
        map,
        PartitionPolicy::EvenTasks(2),
        wl.cpu_secs_per_mb,
    );
    s.run_job(&job).map_stage_time()
}

/// Speculative execution vs HeMT, under two failure models:
/// *persistent* heterogeneity (the Sec. 6.1 container split — speculation
/// wastes duplicate work, HeMT wins) and a *transient* straggler (a
/// sysbench burst mid-stage — speculation rescues HomT).
pub fn speculation_spec() -> SweepSpec {
    let wl = WorkloadConfig::wordcount_2gb();
    let mut spec = SweepSpec::new(
        "Ablation: speculative execution vs HeMT",
        "scenario",
        "map stage time (s)",
    );
    let cell = |spec: &mut SweepSpec,
                series: usize,
                    x: f64,
                    label: &str,
                    cluster: ClusterConfig,
                    policy: PolicyConfig,
                    speculation: Option<Speculation>,
                    base_seed: u64| {
        let wl = wl.clone();
        spec.grid(series, x, label, 5, base_seed, move |seed| {
            speculation_trial(&cluster, &wl, &policy, speculation, seed)
        });
    };

    // Persistent heterogeneity (1.0 vs 0.4 cores, known to the manager).
    let static_cluster = ClusterConfig::containers_1_and_04();
    let s1 = spec.series("persistent 1:0.4");
    cell(
        &mut spec,
        s1,
        0.0,
        "HomT 8",
        static_cluster.clone(),
        PolicyConfig::Homt(8),
        None,
        11,
    );
    cell(
        &mut spec,
        s1,
        0.0,
        "HomT 8 + speculation",
        static_cluster.clone(),
        PolicyConfig::Homt(8),
        Some(Speculation::default()),
        12,
    );
    cell(
        &mut spec,
        s1,
        0.0,
        "HeMT (hints)",
        static_cluster,
        PolicyConfig::HemtFromHints,
        None,
        13,
    );

    // Transient straggler: both nodes nominally equal; node 1 collapses
    // to 10% at t=20 s (mid-stage) — the case speculation was built for.
    let mut transient = two_full_cores(600.0);
    transient.interference[1] = vec![(20.0, 0.1)];
    let s2 = spec.series("transient straggler");
    cell(
        &mut spec,
        s2,
        1.0,
        "HomT 8",
        transient.clone(),
        PolicyConfig::Homt(8),
        None,
        21,
    );
    cell(
        &mut spec,
        s2,
        1.0,
        "HomT 8 + speculation",
        transient,
        PolicyConfig::Homt(8),
        Some(Speculation { quantile: 0.5, multiplier: 1.5, check_interval: 0.1 }),
        22,
    );
    spec
}

pub fn speculation() -> Figure {
    default_runner().run(&speculation_spec())
}

/// Footnote 3: rack-aware placement (cluster-local writer) vs flat-random
/// under a network bottleneck — concentration intensifies uplink
/// competition and slows the stage.
pub fn rack_awareness_spec() -> SweepSpec {
    let wl = WorkloadConfig {
        kind: WorkloadKind::WordCount,
        data_mb: 1024,
        block_mb: 64,
        cpu_secs_per_mb: 0.001, // network-bound
        iterations: 1,
    };
    let cluster = two_full_cores(64.0);
    let mut spec = SweepSpec::new(
        "Ablation: HDFS rack awareness under a 64 Mbps uplink bottleneck",
        "placement",
        "map stage time (s)",
    );
    let cell = |spec: &mut SweepSpec,
                name: &str,
                    x: f64,
                    placement: Placement,
                    base_seed: u64| {
        let series = spec.series(name);
        let cluster = cluster.clone();
        let wl = wl.clone();
        let label = name.to_string();
        spec.grid(series, x, &label, 5, base_seed, move |seed| {
            let mut s = cluster.build_session(SimParams::default(), seed);
            let file = s.hdfs.upload_with_policy(
                wl.data_mb * MB,
                wl.block_mb * MB,
                placement,
                &mut s.rng,
            );
            let job = workloads::wordcount_job(
                file,
                PartitionPolicy::EvenTasks(16),
                PartitionPolicy::EvenTasks(2),
                wl.cpu_secs_per_mb,
            );
            s.run_job(&job).map_stage_time()
        });
    };
    cell(&mut spec, "flat random (paper baseline)", 0.0, Placement::FlatRandom, 31);
    cell(
        &mut spec,
        "rack-aware, local writer",
        1.0,
        Placement::RackAware { racks: 2, writer: Some(0) },
        32,
    );
    spec
}

pub fn rack_awareness() -> Figure {
    default_runner().run(&rack_awareness_spec())
}

/// Footnote 8: the credit planner with stale CloudWatch readings. Credits
/// are read `lag` seconds before the job starts while the nodes keep
/// bursting; the plan equalizes the *stale* curves, so actual finish
/// times spread apart as the lag grows (0 s = exact, 60 s = paid
/// per-minute monitoring, 300 s = free tier).
pub fn stale_credits_spec() -> SweepSpec {
    let read_credits = [4.0, 8.0, 12.0]; // minutes, at reading time
    let w0 = 20.0;
    let burn_per_sec = (1.0 - 0.2) / 60.0; // busy at peak until job start
    let mut spec = SweepSpec::new(
        "Ablation: credit-planner accuracy vs CloudWatch staleness",
        "reading lag (s)",
        "finish-time spread (min)",
    );
    let spread_series = spec.series("finish-time spread");
    let stage_series = spec.series("job completion (max finish)");
    for &lag in &[0.0, 60.0, 300.0] {
        spec.sequence(move || {
            let stale: Vec<CreditCurve> =
                read_credits.iter().map(|&c| CreditCurve::t2_small(c)).collect();
            let actual: Vec<CreditCurve> = read_credits
                .iter()
                .map(|&c| CreditCurve::t2_small((c - lag * burn_per_sec).max(0.0)))
                .collect();
            let p = plan(&stale, w0).expect("solvable");
            // Execute the stale plan on the *actual* curves.
            let finishes: Vec<f64> = actual
                .iter()
                .zip(p.shares.iter())
                .map(|(c, &share)| c.time_for_work(share))
                .collect();
            let max = finishes.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let min = finishes.iter().cloned().fold(f64::INFINITY, f64::min);
            vec![
                Sample { series: spread_series, x: lag, label: String::new(), value: max - min },
                Sample { series: stage_series, x: lag, label: String::new(), value: max },
            ]
        });
    }
    spec
}

pub fn stale_credits() -> Figure {
    default_runner().run(&stale_credits_spec())
}

/// Dispatch to an ablation's sweep spec by CLI name.
pub fn spec_by_name(name: &str) -> Option<SweepSpec> {
    match name {
        "alpha" => Some(alpha_spec()),
        "speculation" => Some(speculation_spec()),
        "rack" | "rack_awareness" => Some(rack_awareness_spec()),
        "stale_credits" | "stale" => Some(stale_credits_spec()),
        _ => None,
    }
}

/// Dispatch for the CLI (`hemt ablation <name>`).
pub fn by_name(name: &str) -> Option<Figure> {
    spec_by_name(name).map(|spec| default_runner().run(&spec))
}

pub const ALL_ABLATIONS: &[&str] = &["alpha", "speculation", "rack", "stale_credits"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_tradeoff_shape() {
        let fig = alpha();
        let jitter = &fig.series[0].points;
        let recovery = &fig.series[1].points;
        // Partition instability falls as alpha grows; recovery cost rises.
        assert!(
            jitter.last().unwrap().stats.mean < 0.5 * jitter[0].stats.mean,
            "high alpha must stabilize the partition: {:?}",
            jitter.iter().map(|p| p.stats.mean).collect::<Vec<_>>()
        );
        assert!(
            recovery.last().unwrap().stats.mean > recovery[0].stats.mean,
            "high alpha must slow recovery: {:?}",
            recovery.iter().map(|p| p.stats.mean).collect::<Vec<_>>()
        );
    }

    #[test]
    fn speculation_helps_transient_not_persistent() {
        let fig = speculation();
        let persistent = &fig.series[0].points;
        let transient = &fig.series[1].points;
        let homt = persistent[0].stats.mean;
        let homt_spec = persistent[1].stats.mean;
        let hemt = persistent[2].stats.mean;
        // Persistent heterogeneity: HeMT beats both HomT variants, and
        // speculation brings no significant benefit.
        assert!(hemt < homt && hemt < homt_spec, "{hemt} vs {homt}/{homt_spec}");
        assert!(homt_spec > homt * 0.95, "speculation shouldn't help much here");
        // Transient straggler: speculation clearly rescues HomT.
        let t_plain = transient[0].stats.mean;
        let t_spec = transient[1].stats.mean;
        assert!(
            t_spec < t_plain * 0.85,
            "speculation must rescue the transient straggler: {t_plain:.1} -> {t_spec:.1}"
        );
    }

    #[test]
    fn rack_awareness_slows_network_bound_stage() {
        let fig = rack_awareness();
        let flat = fig.series[0].points[0].stats.mean;
        let racked = fig.series[1].points[0].stats.mean;
        assert!(
            racked > flat * 1.05,
            "footnote 3: rack awareness must slow the stage: {flat:.1} -> {racked:.1}"
        );
    }

    #[test]
    fn staleness_degrades_plan_quality_monotonically() {
        let fig = stale_credits();
        let spreads: Vec<f64> = fig.series[0].points.iter().map(|p| p.stats.mean).collect();
        assert!(spreads[0] < 1e-9, "exact reading must balance perfectly");
        assert!(spreads[1] > spreads[0] && spreads[2] > spreads[1], "{spreads:?}");
    }
}

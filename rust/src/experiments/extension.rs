//! Extensions beyond the paper's plotted experiments:
//!
//! * [`probed_weights`] — the Sec. 6.2 probing mechanism as a first-class
//!   policy: short trial tasks measure each executor's *effective* speed,
//!   recovering the paper's 1:0.32 fudge factor instead of hard-coding it.
//! * [`four_node`] — generality check on a 4-node mixed cluster (full
//!   core, half core, depleted burstable, interfered node): the paper's
//!   2-node conclusions carry over. Declared as a [`SweepSpec`]
//!   (`four_node_spec()`) whose HomT sweep, probed-HeMT and OA-HeMT
//!   trials all fan out over the worker pool.

use crate::config::{ClusterConfig, NodeConfig, PolicyConfig, WorkloadConfig};
use crate::coordinator::driver::{Session, SimParams};
use crate::coordinator::PartitionPolicy;
use crate::experiments::{default_runner, observe_map_stage, resolve_policy, MB, TRIALS};
use crate::metrics::Figure;
use crate::sweep::SweepSpec;
use crate::workloads;

/// Run one short probe job (`probe_mb` per executor, evenly sized, bound
/// one-per-executor) and return measured speed weights — the paper's
/// "short/trial probing tasks" (Sec. 6.2). Burns a little simulated time
/// and (on burstables) a few credits, exactly like the real mechanism.
pub fn probed_weights(s: &mut Session, probe_mb: u64, cpu_secs_per_mb: f64) -> Vec<f64> {
    let n = s.executors.len();
    let total = probe_mb * n as u64 * MB;
    let file = s.hdfs.upload(total, total, &mut s.rng);
    // Equal probe per executor: HeMT with unit weights binds one equal
    // task to each executor.
    let job = workloads::wordcount_job(
        file,
        PartitionPolicy::Hemt(vec![1.0; n]),
        PartitionPolicy::EvenTasks(n),
        cpu_secs_per_mb,
    );
    let rec = s.run_job(&job);
    let mut est = crate::estimator::SpeedEstimator::new(0.0);
    observe_map_stage(&mut est, &rec, n);
    est.weights(&(0..n).collect::<Vec<_>>())
}

/// Probing on the Sec. 6.2 burstable pair: the measured weight ratio
/// (≈ 0.32) vs the nominal credit-based 0.4 — the fudge factor *learned*,
/// not assumed.
pub fn probe_recovers_fudge_factor() -> (f64, f64) {
    let cluster = ClusterConfig::burstable_pair(600.0);
    let mut s = cluster.build_session(SimParams::default(), 77);
    let w = probed_weights(&mut s, 32, 42.0 / 1024.0);
    (w[1] / w[0], 0.32)
}

/// A 4-node mixed cluster: full core, half core (CFS cap), depleted
/// burstable with contention penalty, and a node under 0.6x interference.
pub fn four_node_cluster() -> ClusterConfig {
    ClusterConfig {
        nodes: vec![
            NodeConfig::Static { cores: 1.0 },
            NodeConfig::Static { cores: 1.0 },
            NodeConfig::Burstable {
                peak: 1.0,
                baseline: 0.4,
                credits: 0.0,
                contention_penalty: 0.8,
            },
            NodeConfig::Static { cores: 1.0 },
        ],
        exec_cpus: vec![1.0, 0.5, 1.0, 1.0],
        interference: vec![vec![], vec![], vec![], vec![(0.0, 0.6)]],
        node_uplink_mbps: 600.0,
        node_downlink_mbps: 600.0,
        hdfs_datanodes: 4,
        hdfs_replication: 2,
        hdfs_uplink_mbps: 600.0,
        hdfs_serving_eta: 0.26,
    }
}

/// Extension experiment: HomT sweep vs probed HeMT vs converged OA-HeMT
/// on the 4-node mixed cluster — the 2-node conclusions generalize.
pub fn four_node_spec() -> SweepSpec {
    let cluster = four_node_cluster();
    let wl = WorkloadConfig::wordcount_2gb();
    let mut spec = SweepSpec::new(
        "Extension: 4-node mixed cluster (1.0 / 0.5 / depleted-burstable / 0.6-interfered)",
        "configuration",
        "map stage time (s)",
    );

    let homt = spec.series("even (HomT sweep)");
    for m in [4usize, 8, 16, 32, 64, 128] {
        let cluster = cluster.clone();
        let wl = wl.clone();
        spec.grid(homt, m as f64, "", TRIALS, 400 + m as u64, move |seed| {
            let mut s = cluster.build_session(SimParams::default(), seed);
            let file = s.hdfs.upload(wl.data_mb * MB, wl.block_mb * MB, &mut s.rng);
            let map = resolve_policy(&PolicyConfig::Homt(m), &s, None);
            let job = workloads::wordcount_job(
                file,
                map,
                PartitionPolicy::EvenTasks(4),
                wl.cpu_secs_per_mb,
            );
            s.run_job(&job).map_stage_time()
        });
    }

    let probed = spec.series("HeMT (one probe round)");
    {
        let cluster = cluster.clone();
        let wl = wl.clone();
        spec.grid(probed, 4.0, "4 (probed)", TRIALS, 500, move |seed| {
            let mut s = cluster.build_session(SimParams::default(), seed);
            let w = probed_weights(&mut s, 32, wl.cpu_secs_per_mb);
            let file = s.hdfs.upload(wl.data_mb * MB, wl.block_mb * MB, &mut s.rng);
            let job = workloads::wordcount_job(
                file,
                PartitionPolicy::Hemt(w.clone()),
                PartitionPolicy::Hemt(w),
                wl.cpu_secs_per_mb,
            );
            s.run_job(&job).map_stage_time()
        });
    }

    // Converged OA-HeMT: weights refined over full-size warmup jobs (the
    // paper's Sec. 5 mechanism) — steady-state accuracy the probe can't
    // reach on a bursty node.
    let adaptive = spec.series("OA-HeMT (converged)");
    {
        let cluster = cluster.clone();
        let wl = wl.clone();
        spec.grid(adaptive, 4.0, "4 (adaptive)", TRIALS, 600, move |seed| {
            let mut s = cluster.build_session(SimParams::default(), seed);
            let mut est = crate::estimator::SpeedEstimator::new(0.25);
            let mut last = 0.0;
            for _ in 0..4 {
                let file = s.hdfs.upload(wl.data_mb * MB, wl.block_mb * MB, &mut s.rng);
                let policy = resolve_policy(
                    &PolicyConfig::HemtAdaptive { alpha: 0.25 },
                    &s,
                    if est.is_cold() { None } else { Some(&est) },
                );
                let job = workloads::wordcount_job(
                    file,
                    policy.clone(),
                    policy,
                    wl.cpu_secs_per_mb,
                );
                let rec = s.run_job(&job);
                observe_map_stage(&mut est, &rec, 4);
                last = rec.map_stage_time();
            }
            last
        });
    }
    spec
}

pub fn four_node() -> Figure {
    default_runner().run(&four_node_spec())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_learns_the_fudge_factor() {
        // The paper hard-measured 1:0.32 on EC2; our probe mechanism must
        // recover it from the simulated contention-penalized burstable.
        let (measured, expected) = probe_recovers_fudge_factor();
        assert!(
            (measured - expected).abs() < 0.03,
            "probed ratio {measured:.3} should be ~{expected}"
        );
    }

    #[test]
    fn probed_weights_sane_on_static_split() {
        let cluster = ClusterConfig::containers_1_and_04();
        let mut s = cluster.build_session(SimParams::default(), 3);
        let w = probed_weights(&mut s, 32, 42.0 / 1024.0);
        let ratio = w[1] / w[0];
        assert!((ratio - 0.4).abs() < 0.03, "static probe ratio {ratio:.3}");
    }

    #[test]
    fn four_node_converged_hemt_beats_best_homt() {
        let fig = four_node();
        let best_homt = fig.series[0].best().unwrap().stats.mean;
        let probed = fig.series[1].points[0].stats.mean;
        let adaptive = fig.series[2].points[0].stats.mean;
        // Converged OA-HeMT wins outright; a single probe round gets
        // within ~10% of the best (heavily-tuned) HomT — an honest
        // depiction of when fine HomT is competitive (4 executors, cheap
        // per-task overhead).
        assert!(
            adaptive < best_homt,
            "4-node adaptive HeMT {adaptive:.1} must beat best HomT {best_homt:.1}"
        );
        assert!(
            probed < best_homt * 1.1,
            "one probe round should land near best HomT: {probed:.1} vs {best_homt:.1}"
        );
        // Theoretical floor sanity: ~2.42 cores over 84 core-s = ~34.7 s.
        assert!(adaptive > 32.0 && adaptive < 50.0, "adaptive {adaptive:.1}");
    }
}

//! Per-figure experiment drivers: every table and figure of the paper's
//! evaluation, regenerated on the simulation substrate.
//!
//! Each figure is declared as a [`SweepSpec`] (`figN_spec()`) — a
//! cluster × workload × policy × trial grid, plus stateful sequence units
//! for the adaptive/closed-form figures — and executed through the
//! multi-threaded [`SweepRunner`] (`figN()` convenience wrappers use
//! [`default_runner`]). Output is bit-identical for any worker count; see
//! `rust/tests/golden_figures.rs`. The per-figure benches
//! (`rust/benches/`) and the CLI (`hemt figure N`) print the results.

pub mod ablations;
pub mod extension;

use crate::analysis;
use crate::config::{ClusterConfig, NodeConfig, PolicyConfig, WorkloadConfig};
use crate::estimator::credits::CreditCurve;
use crate::estimator::SpeedEstimator;
use crate::metrics::Figure;
use crate::sweep::{Metric, Sample, Scenario, SweepRunner, SweepSpec};
use crate::workloads;

pub use crate::sweep::{kmeans_total_time, pagerank_total_time, resolve_policy, MB};

use crate::sweep::ProductSweepSpec;

/// Default trial count behind every ±σ beam.
pub const TRIALS: usize = 5;

/// The sweep runner behind every `figN()` convenience wrapper: worker
/// count from `HEMT_SWEEP_THREADS`, defaulting to available parallelism.
pub fn default_runner() -> SweepRunner {
    SweepRunner::from_env()
}

/// Feed a finished map stage into the OA-HeMT estimator (moved to the
/// closed-loop driver; re-exported here for the figure drivers,
/// examples and tests that always imported it from `experiments`).
pub use crate::coordinator::adaptive::observe_map_stage;

/// Shorthand for the per-figure scenario grid cell: the named policy on
/// the given cluster/workload, `TRIALS` trials, map-stage metric (for
/// K-Means / PageRank workloads the trial reports the workload total).
fn scenario_of(
    cluster: &ClusterConfig,
    wl: &WorkloadConfig,
    policy: PolicyConfig,
    base_seed: u64,
) -> Scenario {
    Scenario {
        cluster: cluster.clone(),
        workload: wl.clone(),
        policy,
        dynamics: crate::dynamics::DynamicsConfig::steady(),
        metric: Metric::MapStageTime,
        trials: TRIALS,
        base_seed,
    }
}

// ---------------------------------------------------------------- Fig 4

/// Fig. 4: closed-form p1, p2 vs datanode count (r = 2).
pub fn fig4_spec() -> SweepSpec {
    let mut spec = SweepSpec::new(
        "Fig 4: same-datanode read collision probability (r=2)",
        "n (datanodes)",
        "probability",
    );
    let s1 = spec.series("p1 (same block)");
    let s2 = spec.series("p2 (different blocks)");
    spec.sequence(move || {
        let mut out = Vec::new();
        for (n, p1, p2) in analysis::fig4_series(2, 30) {
            out.push(Sample { series: s1, x: n as f64, label: String::new(), value: p1 });
            out.push(Sample { series: s2, x: n as f64, label: String::new(), value: p2 });
        }
        out
    });
    spec
}

pub fn fig4() -> Figure {
    default_runner().run(&fig4_spec())
}

// ---------------------------------------------------------------- Fig 5

/// Fig. 5: stage completion time vs partition count when datanode uplinks
/// (64 Mbps, n=4, r=2) are the universal bottleneck — more partitions
/// means more same-block reads colliding on uplinks (Claim 2) plus
/// per-task overhead.
pub fn fig5_spec() -> SweepSpec {
    let cluster = ClusterConfig {
        nodes: vec![NodeConfig::Static { cores: 1.0 }, NodeConfig::Static { cores: 1.0 }],
        exec_cpus: vec![1.0, 1.0],
        interference: vec![vec![], vec![]],
        node_uplink_mbps: 1000.0,
        node_downlink_mbps: 1000.0,
        hdfs_datanodes: 4,
        hdfs_replication: 2,
        hdfs_uplink_mbps: 64.0,
        hdfs_serving_eta: 0.26,
    };
    let wl = WorkloadConfig {
        kind: crate::config::WorkloadKind::WordCount,
        data_mb: 1024,
        block_mb: 128,
        cpu_secs_per_mb: 0.001, // network-bound
        iterations: 1,
    };
    let mut spec = SweepSpec::new(
        "Fig 5: stage completion vs partitions, network-bottlenecked (64 Mbps uplinks)",
        "partitions",
        "stage time (s)",
    );
    let s = spec.series("HomT (even partitioning)");
    for m in [2usize, 4, 8, 16, 32, 64] {
        spec.scenario(
            s,
            m as f64,
            "",
            scenario_of(&cluster, &wl, PolicyConfig::Homt(m), 10 + m as u64),
        );
    }
    spec
}

pub fn fig5() -> Figure {
    default_runner().run(&fig5_spec())
}

// ---------------------------------------------------------------- Fig 7

/// Fig. 7: OA-HeMT adapting to injected interference across a 50-job
/// WordCount sequence (alpha = 0). Returns per-job map time and the
/// fraction of data assigned to the interfered node. One stateful
/// sequence unit: jobs share a session, so they cannot be split into
/// independent trials.
pub fn fig7_spec() -> SweepSpec {
    let mut spec = SweepSpec::new(
        "Fig 7: OA-HeMT rebalancing under injected interference (alpha=0)",
        "job index",
        "seconds / share",
    );
    let times = spec.series("job map-stage time");
    let share = spec.series("node-1 data share");
    spec.sequence(move || {
        let wl = WorkloadConfig {
            kind: crate::config::WorkloadKind::WordCount,
            data_mb: 512,
            block_mb: 256,
            cpu_secs_per_mb: 42.0 / 1024.0,
            iterations: 1,
        };
        let cluster = ClusterConfig {
            nodes: vec![
                NodeConfig::Static { cores: 1.0 },
                NodeConfig::Static { cores: 1.0 },
            ],
            exec_cpus: vec![1.0, 1.0],
            interference: vec![vec![], vec![]],
            node_uplink_mbps: 600.0,
            node_downlink_mbps: 600.0,
            hdfs_datanodes: 4,
            hdfs_replication: 2,
            hdfs_uplink_mbps: 600.0,
            hdfs_serving_eta: 0.26,
        };
        let mut s = cluster.build_session(crate::coordinator::driver::SimParams::default(), 42);
        let mut est = SpeedEstimator::new(0.0);
        let mut out = Vec::new();
        for job_idx in 0..50usize {
            // Interference events: sysbench-like load lands on node 1
            // before job 15 (halving it) and intensifies before job 32.
            if job_idx == 15 {
                let t = s.engine.now;
                s.engine.set_node_interference(1, vec![(t, 0.5)]);
            }
            if job_idx == 32 {
                let t = s.engine.now;
                s.engine.set_node_interference(1, vec![(t, 0.25)]);
            }
            let file = s.hdfs.upload(wl.data_mb * MB, wl.block_mb * MB, &mut s.rng);
            let policy = resolve_policy(
                &PolicyConfig::HemtAdaptive { alpha: 0.0 },
                &s,
                if est.is_cold() { None } else { Some(&est) },
            );
            let job =
                workloads::wordcount_job(file, policy.clone(), policy, wl.cpu_secs_per_mb);
            let rec = s.run_job(&job);
            observe_map_stage(&mut est, &rec, 2);
            out.push(Sample {
                series: times,
                x: job_idx as f64,
                label: String::new(),
                value: rec.map_stage_time(),
            });
            let by_exec = rec.stages[0].executor_bytes(2);
            let frac = by_exec[1] as f64 / (by_exec[0] + by_exec[1]) as f64;
            out.push(Sample {
                series: share,
                x: job_idx as f64,
                label: String::new(),
                value: frac,
            });
        }
        out
    });
    spec
}

pub fn fig7() -> Figure {
    default_runner().run(&fig7_spec())
}

// ---------------------------------------------------------------- Fig 8

/// Fig. 8: OA-HeMT convergence when executors differ by initial
/// provisioning (1.0 vs 0.4 cores): the map stage reaches the optimal
/// ~60 s within two trials.
pub fn fig8_spec() -> SweepSpec {
    let mut spec = SweepSpec::new(
        "Fig 8: OA-HeMT convergence with 1.0 + 0.4 core executors",
        "trial",
        "map stage time (s)",
    );
    let times = spec.series("map-stage time (adaptive)");
    spec.sequence(move || {
        let cluster = ClusterConfig::containers_1_and_04();
        let wl = WorkloadConfig::wordcount_2gb();
        let mut s = cluster.build_session(crate::coordinator::driver::SimParams::default(), 7);
        let mut est = SpeedEstimator::new(0.0);
        let mut out = Vec::new();
        for job_idx in 0..8usize {
            let file = s.hdfs.upload(wl.data_mb * MB, wl.block_mb * MB, &mut s.rng);
            let policy = resolve_policy(
                &PolicyConfig::HemtAdaptive { alpha: 0.0 },
                &s,
                if est.is_cold() { None } else { Some(&est) },
            );
            let job =
                workloads::wordcount_job(file, policy.clone(), policy, wl.cpu_secs_per_mb);
            let rec = s.run_job(&job);
            observe_map_stage(&mut est, &rec, 2);
            out.push(Sample {
                series: times,
                x: job_idx as f64,
                label: String::new(),
                value: rec.map_stage_time(),
            });
        }
        out
    });
    spec
}

pub fn fig8() -> Figure {
    default_runner().run(&fig8_spec())
}

// ---------------------------------------------------------------- Fig 9

/// Fig. 9: static containers (1.0 + 0.4 cores), WordCount 2 GB — the
/// HomT U-curve vs the HeMT beam from cluster-manager resource hints.
pub fn fig9_spec() -> SweepSpec {
    let cluster = ClusterConfig::containers_1_and_04();
    let wl = WorkloadConfig::wordcount_2gb();
    let mut spec = SweepSpec::new(
        "Fig 9: even partitioning vs HeMT, statically provisioned containers",
        "partitions",
        "map stage time (s)",
    );
    let homt = spec.series("even (HomT sweep)");
    for m in [2usize, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64] {
        spec.scenario(
            homt,
            m as f64,
            "",
            scenario_of(&cluster, &wl, PolicyConfig::Homt(m), 100 + m as u64),
        );
    }
    let hemt = spec.series("HeMT (Mesos resource info)");
    spec.scenario(
        hemt,
        2.0,
        "2 (1:0.4)",
        scenario_of(&cluster, &wl, PolicyConfig::HemtFromHints, 900),
    );
    spec
}

pub fn fig9() -> Figure {
    default_runner().run(&fig9_spec())
}

// --------------------------------------------------------- Figs 10-12

/// Figs. 10–12: the burstable-credit planner's closed forms — W(t) for a
/// t2.small with 4 credits, the superposed curve for credits {4, 8, 12},
/// and the t' = 80/11 solve giving the 3:4:4 split of a 20-minute job.
pub fn fig10_12_spec() -> SweepSpec {
    let mut spec = SweepSpec::new(
        "Figs 10-12: burstable credit planner (t2.small, credits {4,8,12}, W0=20)",
        "t (minutes)",
        "work (CPU-minutes)",
    );
    let w_single = spec.series("W(t), 4 credits (Fig 10)");
    let w_sum = spec.series("superposed W_s(t) (Fig 12)");
    let solve = spec.series("t' and shares");
    spec.sequence(move || {
        let mut out = Vec::new();
        let single = CreditCurve::t2_small(4.0);
        for t in 0..=10 {
            out.push(Sample {
                series: w_single,
                x: t as f64,
                label: String::new(),
                value: single.work_by(t as f64),
            });
        }
        let curves = [
            CreditCurve::t2_small(4.0),
            CreditCurve::t2_small(8.0),
            CreditCurve::t2_small(12.0),
        ];
        for t in 0..=20 {
            let total: f64 = curves.iter().map(|c| c.work_by(t as f64)).sum();
            out.push(Sample {
                series: w_sum,
                x: t as f64,
                label: String::new(),
                value: total,
            });
        }
        let plan = crate::estimator::credits::plan(&curves, 20.0).expect("solvable");
        out.push(Sample {
            series: solve,
            x: plan.t_prime,
            label: "t'".to_string(),
            value: plan.t_prime,
        });
        for (i, share) in plan.shares.iter().enumerate() {
            out.push(Sample {
                series: solve,
                x: plan.t_prime,
                label: format!("W_{}(t')", i + 1),
                value: *share,
            });
        }
        out
    });
    spec
}

pub fn fig10_12() -> Figure {
    default_runner().run(&fig10_12_spec())
}

// ------------------------------------------------------- Figs 13/14/15

/// Figs. 13–15: burstable pair (one credit-rich node, one depleted with
/// the measured contention penalty), HomT sweep vs naive HeMT (1:0.4) vs
/// fudge-adjusted HeMT (1:0.32), at the given HDFS uplink bandwidth.
pub fn fig_burstable_spec(hdfs_mbps: f64, fig_name: &str) -> SweepSpec {
    let cluster = ClusterConfig::burstable_pair(hdfs_mbps);
    let wl = WorkloadConfig::wordcount_2gb();
    let mut spec = SweepSpec::new(fig_name, "partitions", "map stage time (s)");
    let homt = spec.series("even (HomT sweep)");
    for m in [2usize, 4, 8, 16, 32, 64] {
        spec.scenario(
            homt,
            m as f64,
            "",
            scenario_of(&cluster, &wl, PolicyConfig::Homt(m), 200 + m as u64),
        );
    }
    let naive = spec.series("HeMT naive (1:0.4)");
    spec.scenario(
        naive,
        2.0,
        "2 (1:0.4)",
        scenario_of(&cluster, &wl, PolicyConfig::HemtStatic(vec![1.0, 0.4]), 300),
    );
    let adjusted = spec.series("HeMT adjusted (1:0.32)");
    spec.scenario(
        adjusted,
        2.0,
        "2 (1:0.32)",
        scenario_of(&cluster, &wl, PolicyConfig::HemtStatic(vec![1.0, 0.32]), 400),
    );
    spec
}

pub fn fig_burstable(hdfs_mbps: f64, fig_name: &str) -> Figure {
    default_runner().run(&fig_burstable_spec(hdfs_mbps, fig_name))
}

pub fn fig13_spec() -> SweepSpec {
    fig_burstable_spec(600.0, "Fig 13: burstable pair, CPU-bound (~600 Mbps uplinks)")
}

pub fn fig13() -> Figure {
    default_runner().run(&fig13_spec())
}

pub fn fig14_spec() -> SweepSpec {
    fig_burstable_spec(480.0, "Fig 14: burstable pair, ~480 Mbps uplinks (still CPU-bound)")
}

pub fn fig14() -> Figure {
    default_runner().run(&fig14_spec())
}

pub fn fig15_spec() -> SweepSpec {
    fig_burstable_spec(
        250.0,
        "Fig 15: burstable pair, ~250 Mbps uplinks (fast node network-bound)",
    )
}

pub fn fig15() -> Figure {
    default_runner().run(&fig15_spec())
}

// ---------------------------------------------------------------- Fig 17

/// Fig. 17: K-Means job finish time, HeMT vs default vs HomT.
pub fn fig17_spec() -> SweepSpec {
    let cluster = ClusterConfig::containers_1_and_04();
    let wl = WorkloadConfig::kmeans_256mb();
    let mut spec = SweepSpec::new(
        "Fig 17: K-Means (30 iterations, 256 MB) finish time",
        "configuration",
        "job finish time (s)",
    );
    let add = |spec: &mut SweepSpec, name: &str, x: f64, policy: PolicyConfig, seed: u64| {
        let series = spec.series(name);
        spec.scenario(series, x, name, scenario_of(&cluster, &wl, policy, seed));
    };
    add(&mut spec, "default (2 blocks)", 2.0, PolicyConfig::Default, 500);
    for m in [4usize, 8, 16, 32] {
        add(
            &mut spec,
            &format!("HomT {m}-way"),
            m as f64,
            PolicyConfig::Homt(m),
            500 + m as u64,
        );
    }
    add(&mut spec, "HeMT (1:0.4)", 2.0, PolicyConfig::HemtFromHints, 600);
    spec
}

pub fn fig17() -> Figure {
    default_runner().run(&fig17_spec())
}

// ---------------------------------------------------------------- Fig 18

/// Fig. 18: PageRank finish time — microtask-sensitive because stages are
/// short, so per-task overhead dominates at high partition counts.
pub fn fig18_spec() -> SweepSpec {
    let cluster = ClusterConfig::containers_1_and_04();
    let wl = WorkloadConfig::pagerank_256mb();
    let mut spec = SweepSpec::new(
        "Fig 18: PageRank (100 iterations, 256 MB) finish time",
        "configuration",
        "job finish time (s)",
    );
    let add = |spec: &mut SweepSpec, name: &str, x: f64, policy: PolicyConfig, seed: u64| {
        let series = spec.series(name);
        spec.scenario(series, x, name, scenario_of(&cluster, &wl, policy, seed));
    };
    add(&mut spec, "default (2-way)", 2.0, PolicyConfig::Default, 700);
    for m in [4usize, 8, 16, 32, 64] {
        add(
            &mut spec,
            &format!("HomT {m}-way"),
            m as f64,
            PolicyConfig::Homt(m),
            700 + m as u64,
        );
    }
    add(&mut spec, "HeMT (1:0.4)", 2.0, PolicyConfig::HemtFromHints, 800);
    spec
}

pub fn fig18() -> Figure {
    default_runner().run(&fig18_spec())
}

// ---------------------------------------------------------------- headline

/// The paper's headline: HeMT improves average completion times ~10% over
/// the default system across realistic workloads. Compares HeMT vs the
/// *best even* configuration per scenario and vs the default.
pub fn headline_spec() -> SweepSpec {
    let mut spec = SweepSpec::new(
        "Headline: HeMT vs default / best-HomT across workloads",
        "scenario",
        "completion time (s)",
    );
    // WordCount on static containers.
    let c1 = ClusterConfig::containers_1_and_04();
    let wc = WorkloadConfig::wordcount_2gb();
    let s = spec.series("wordcount/static");
    spec.scenario(s, 0.0, "default", scenario_of(&c1, &wc, PolicyConfig::Default, 31));
    spec.scenario(s, 0.0, "best HomT (8)", scenario_of(&c1, &wc, PolicyConfig::Homt(8), 32));
    spec.scenario(s, 0.0, "HeMT", scenario_of(&c1, &wc, PolicyConfig::HemtFromHints, 33));
    // WordCount on the burstable pair.
    let c2 = ClusterConfig::burstable_pair(600.0);
    let s = spec.series("wordcount/burstable");
    spec.scenario(s, 1.0, "default", scenario_of(&c2, &wc, PolicyConfig::Default, 41));
    spec.scenario(s, 1.0, "best HomT (8)", scenario_of(&c2, &wc, PolicyConfig::Homt(8), 42));
    spec.scenario(
        s,
        1.0,
        "HeMT (fudged)",
        scenario_of(&c2, &wc, PolicyConfig::HemtStatic(vec![1.0, 0.32]), 43),
    );
    // K-Means and PageRank on static containers.
    let km = WorkloadConfig::kmeans_256mb();
    let s = spec.series("kmeans/static");
    for (label, pol, seed) in [
        ("default", PolicyConfig::Default, 51u64),
        ("best HomT (8)", PolicyConfig::Homt(8), 52),
        ("HeMT", PolicyConfig::HemtFromHints, 53),
    ] {
        spec.scenario(s, 2.0, label, scenario_of(&c1, &km, pol, seed));
    }
    let pr = WorkloadConfig::pagerank_256mb();
    let s = spec.series("pagerank/static");
    for (label, pol, seed) in [
        ("default", PolicyConfig::Default, 61u64),
        ("best HomT (4)", PolicyConfig::Homt(4), 62),
        ("HeMT", PolicyConfig::HemtFromHints, 63),
    ] {
        spec.scenario(s, 3.0, label, scenario_of(&c1, &pr, pol, seed));
    }
    spec
}

pub fn headline() -> Figure {
    default_runner().run(&headline_spec())
}

// ---------------------------------------------------------- product sweep

/// The built-in whole-grid product sweep (clusters × workloads × policies
/// × granularities), expanded to a flat spec — `hemt sweep` and the
/// `product_sweep` bench run this when given no custom product.
pub fn product_sweep_spec() -> SweepSpec {
    ProductSweepSpec::tiny_tasks_regimes().to_spec()
}

/// `hemt figure pruned_scale` / `hemt sweep --preset cluster_scale`:
/// heterogeneous clusters of growing size × HomT granularity ladder vs
/// hint-HeMT vs pruned HeMT ([`crate::partition::prune_weights`]) — the
/// datacenter-scale regime the sharded engine exists for, at CI-sized
/// node counts.
pub fn pruned_scale_spec() -> SweepSpec {
    ProductSweepSpec::cluster_scale_regimes().to_spec()
}

// ------------------------------------------------------------- dynamics

/// `hemt dynamics` / `hemt figure dyn_compare`: Adaptive-HeMT vs static
/// HeMT vs HomT per capacity-program family (mean ± σ over rounds).
pub fn dynamics_comparison_spec() -> SweepSpec {
    crate::dynamics::comparison_spec(
        crate::dynamics::DEFAULT_ROUNDS,
        crate::dynamics::COMPARISON_BASE_SEED,
    )
}

/// `hemt steal` / `hemt figure dyn_steal`: Steal-HeMT (mid-stage
/// split + steal) vs Adaptive-HeMT vs static HeMT vs HomT per
/// capacity-program family.
pub fn dynamics_steal_spec() -> SweepSpec {
    crate::dynamics::steal_comparison_spec(
        crate::dynamics::DEFAULT_ROUNDS,
        crate::dynamics::COMPARISON_BASE_SEED,
    )
}

/// `hemt steal --streams` / `hemt figure net_steal`: stream-splitting
/// stealing (in-flight reads re-issued from a different replica) vs
/// CPU-only stealing vs static HeMT vs HomT, on the network-bound
/// testbed under spot/markov dynamics.
pub fn net_steal_spec() -> SweepSpec {
    crate::dynamics::net_steal_comparison_spec(
        crate::dynamics::DEFAULT_ROUNDS,
        crate::dynamics::NET_STEAL_BASE_SEED,
    )
}

/// `hemt dynamics --correlated` / `hemt figure rack_steal`: the steal
/// arm set under *rack-correlated* shared-event degradation — every node
/// rides one realization, so thieves degrade with victims and stealing's
/// edge collapses toward parity.
pub fn rack_steal_spec() -> SweepSpec {
    crate::dynamics::correlated_steal_comparison_spec(
        crate::dynamics::DEFAULT_ROUNDS,
        crate::dynamics::CORRELATED_BASE_SEED,
    )
}

/// `hemt dynamics --correlated` / `hemt figure link_degrade`: HeMT vs
/// HomT on the 200 Mbps read-heavy testbed with the datanode uplinks
/// themselves time-varying (compiled LinkPrograms replayed mid-stage
/// through the dirty-link incremental solve).
pub fn link_degrade_spec() -> SweepSpec {
    crate::dynamics::link_degrade_comparison_spec(
        crate::dynamics::DEFAULT_ROUNDS,
        crate::dynamics::LINK_DEGRADE_BASE_SEED,
    )
}

/// `hemt dynamics --auto` / `hemt figure auto_granularity`: the online
/// granularity controller ([`crate::coordinator::granularity`]) vs all
/// four fixed arms on the historic comparison families and seeds — the
/// fixed arms reproduce their historic values bit for bit.
pub fn auto_granularity_spec() -> SweepSpec {
    crate::dynamics::auto_granularity_spec(
        crate::dynamics::DEFAULT_ROUNDS,
        crate::dynamics::COMPARISON_BASE_SEED,
    )
}

/// `hemt dynamics --auto` / `hemt figure controller_grid`: the headline
/// controller-vs-fixed-policy grid across every compute-bound dynamics
/// family (independent and rack-correlated). Acceptance: the controller
/// matches or beats the best fixed arm on every family.
pub fn controller_grid_spec() -> SweepSpec {
    crate::dynamics::controller_grid_spec(
        crate::dynamics::DEFAULT_ROUNDS,
        crate::dynamics::CONTROLLER_GRID_BASE_SEED,
    )
}

/// Round-by-round adaptation trajectory under Markov-modulated
/// throttling (the dynamics analogue of Fig. 7).
pub fn dynamics_markov_spec() -> SweepSpec {
    crate::dynamics::trajectory_spec("markov", 16, crate::dynamics::COMPARISON_BASE_SEED)
}

/// Round-by-round trajectory under spot revocation + delayed
/// replacement.
pub fn dynamics_spot_spec() -> SweepSpec {
    crate::dynamics::trajectory_spec("spot", 16, crate::dynamics::COMPARISON_BASE_SEED)
}

/// Dispatch to a figure's sweep spec by CLI name.
pub fn spec_by_name(name: &str) -> Option<SweepSpec> {
    match name {
        "4" | "fig4" => Some(fig4_spec()),
        "5" | "fig5" => Some(fig5_spec()),
        "7" | "fig7" => Some(fig7_spec()),
        "8" | "fig8" => Some(fig8_spec()),
        "9" | "fig9" => Some(fig9_spec()),
        "10" | "11" | "12" | "fig10_12" => Some(fig10_12_spec()),
        "13" | "fig13" => Some(fig13_spec()),
        "14" | "fig14" => Some(fig14_spec()),
        "15" | "fig15" => Some(fig15_spec()),
        "17" | "fig17" => Some(fig17_spec()),
        "18" | "fig18" => Some(fig18_spec()),
        "headline" => Some(headline_spec()),
        "4node" | "extension" => Some(extension::four_node_spec()),
        "product" | "sweep" => Some(product_sweep_spec()),
        "dynamics" | "dyn_compare" => Some(dynamics_comparison_spec()),
        "dyn_markov" => Some(dynamics_markov_spec()),
        "dyn_spot" => Some(dynamics_spot_spec()),
        "steal" | "dyn_steal" => Some(dynamics_steal_spec()),
        "net_steal" => Some(net_steal_spec()),
        "rack_steal" => Some(rack_steal_spec()),
        "link_degrade" => Some(link_degrade_spec()),
        "pruned_scale" | "cluster_scale" => Some(pruned_scale_spec()),
        "auto" | "auto_granularity" => Some(auto_granularity_spec()),
        "controller_grid" => Some(controller_grid_spec()),
        _ => None,
    }
}

/// Dispatch by figure name for the CLI (runs through [`default_runner`]).
pub fn by_name(name: &str) -> Option<Figure> {
    spec_by_name(name).map(|spec| default_runner().run(&spec))
}

/// All figure names, for `hemt figure all`.
pub const ALL_FIGURES: &[&str] = &[
    "fig4", "fig5", "fig7", "fig8", "fig9", "fig10_12", "fig13", "fig14", "fig15",
    "fig17", "fig18", "headline", "extension", "dyn_compare", "dyn_markov", "dyn_spot",
    "dyn_steal", "net_steal", "rack_steal", "link_degrade", "pruned_scale",
    "auto_granularity", "controller_grid",
];

/// One figure-registry entry: the canonical name plus a one-line
/// description (what `hemt figure --list` and the serve layer's
/// `GET /figures` show).
#[derive(Debug, Clone, Copy)]
pub struct FigureInfo {
    pub name: &'static str,
    pub description: &'static str,
}

/// The figure registry as data: one entry per [`ALL_FIGURES`] name, in
/// the same order (asserted by a test). [`spec_by_name`] accepts every
/// `name` here.
pub const FIGURES: &[FigureInfo] = &[
    FigureInfo {
        name: "fig4",
        description: "Claim 2: same-datanode collision probabilities p1/p2 vs cluster size",
    },
    FigureInfo {
        name: "fig5",
        description: "Network-bottleneck regime: HomT vs HeMT map-stage times on 200 Mbps HDFS",
    },
    FigureInfo {
        name: "fig7",
        description: "OA-HeMT adaptation rounds under synthetic interference",
    },
    FigureInfo {
        name: "fig8",
        description: "OA-HeMT adaptation on the provisioned-container testbed",
    },
    FigureInfo {
        name: "fig9",
        description: "Static containers (1.0/0.4 cores): HomT granularity U-curve vs HeMT",
    },
    FigureInfo {
        name: "fig10_12",
        description: "Burstable credit planner: simultaneous-finish split and t'",
    },
    FigureInfo {
        name: "fig13",
        description: "Burstable pair, CPU-bound WordCount: HomT vs HeMT vs planner",
    },
    FigureInfo {
        name: "fig14",
        description: "Burstable pair on 480 Mbps HDFS uplinks",
    },
    FigureInfo {
        name: "fig15",
        description: "Burstable pair on 250 Mbps HDFS uplinks",
    },
    FigureInfo {
        name: "fig17",
        description: "K-Means (30 iterations): cached-partition totals per policy",
    },
    FigureInfo {
        name: "fig18",
        description: "PageRank (100 iterations): shuffle-chained totals per policy",
    },
    FigureInfo {
        name: "headline",
        description: "Headline summary: every testbed's best HomT vs HeMT",
    },
    FigureInfo {
        name: "extension",
        description: "Beyond-paper 4-node heterogeneous cluster extension",
    },
    FigureInfo {
        name: "dyn_compare",
        description: "Adaptive-HeMT vs static-HeMT vs HomT across capacity-program families",
    },
    FigureInfo {
        name: "dyn_markov",
        description: "Round-by-round adaptation trajectory under Markov throttling",
    },
    FigureInfo {
        name: "dyn_spot",
        description: "Round-by-round trajectory under spot revocation + replacement",
    },
    FigureInfo {
        name: "dyn_steal",
        description: "Steal-HeMT vs adaptive/static/HomT across capacity-program families",
    },
    FigureInfo {
        name: "net_steal",
        description: "Stream-splitting vs CPU-only stealing on the network-bound testbed",
    },
    FigureInfo {
        name: "rack_steal",
        description: "Steal arms under rack-correlated shared-event degradation",
    },
    FigureInfo {
        name: "link_degrade",
        description: "HeMT vs HomT with time-varying HDFS uplink capacities",
    },
    FigureInfo {
        name: "pruned_scale",
        description: "Cluster-scale ladder: HomT vs hint-HeMT vs pruned-class HeMT",
    },
    FigureInfo {
        name: "auto_granularity",
        description: "Online granularity controller vs fixed arms on the historic families",
    },
    FigureInfo {
        name: "controller_grid",
        description: "Headline grid: auto controller vs every fixed policy, all dynamics families",
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure_registry_matches_all_figures() {
        let names: Vec<&str> = FIGURES.iter().map(|f| f.name).collect();
        assert_eq!(names, ALL_FIGURES, "FIGURES must mirror ALL_FIGURES in order");
        for f in FIGURES {
            assert!(spec_by_name(f.name).is_some(), "unresolvable figure '{}'", f.name);
            assert!(!f.description.is_empty(), "figure '{}' needs a description", f.name);
        }
    }

    #[test]
    fn fig9_shape_hemt_beats_best_homt_and_u_curve() {
        let fig = fig9();
        let homt = &fig.series[0];
        let hemt = &fig.series[1];
        let best_homt = homt.best().unwrap().stats.mean;
        let hemt_mean = hemt.points[0].stats.mean;
        assert!(
            hemt_mean < best_homt,
            "HeMT {hemt_mean:.1}s must beat best HomT {best_homt:.1}s"
        );
        // U-shape: the coarsest and finest partitionings are both worse
        // than the best interior configuration.
        let first = homt.points.first().unwrap().stats.mean;
        let last = homt.points.last().unwrap().stats.mean;
        assert!(first > best_homt + 1.0, "left arm missing: {first} vs {best_homt}");
        assert!(last > best_homt + 1.0, "right arm missing: {last} vs {best_homt}");
        // Optimal region near the paper's ~60 s.
        assert!((50.0..80.0).contains(&hemt_mean), "HeMT time {hemt_mean}");
    }

    #[test]
    fn fig13_shape_fudged_hemt_wins() {
        let fig = fig13();
        let homt_best = fig.series[0].best().unwrap().stats.mean;
        let naive = fig.series[1].points[0].stats.mean;
        let adjusted = fig.series[2].points[0].stats.mean;
        assert!(
            adjusted < naive,
            "fudge factor must help: adjusted {adjusted:.1} vs naive {naive:.1}"
        );
        assert!(
            adjusted < homt_best,
            "adjusted HeMT {adjusted:.1} must beat best HomT {homt_best:.1}"
        );
    }

    #[test]
    fn fig15_shape_hemt_dominates_under_network_bottleneck() {
        let fig = fig15();
        let homt = &fig.series[0];
        let homt8 = homt.points.iter().find(|p| p.x == 8.0).unwrap().stats.mean;
        let homt_best = homt.best().unwrap().stats.mean;
        let naive = fig.series[1].points[0].stats.mean;
        let adjusted = fig.series[2].points[0].stats.mean;
        // Paper's Fig 15 claims: (a) 8-way — among the best configs under
        // ample bandwidth (Fig 13) — is "no longer one of the best" here;
        assert!(
            homt8 > homt_best + 1.0,
            "8-way ({homt8:.1}) should degrade vs best HomT ({homt_best:.1})"
        );
        // (b) even naive credit-based HeMT now beats the previous champion
        // configuration (it lost to it clearly in Fig 13);
        assert!(naive < homt8, "naive {naive:.1} vs 8-way {homt8:.1}");
        // (c) adjusted HeMT beats every HomT configuration.
        assert!(
            adjusted < homt_best,
            "adjusted {adjusted:.1} vs best HomT {homt_best:.1}"
        );
    }

    #[test]
    fn fig13_vs_fig15_crossover() {
        // The cross-figure shape: under ample bandwidth best-HomT clearly
        // beats naive HeMT; under the 250 Mbps bottleneck the gap closes
        // sharply (the paper's "started to significantly outperform").
        let f13 = fig13();
        let f15 = fig15();
        let gap13 = f13.series[1].points[0].stats.mean - f13.series[0].best().unwrap().stats.mean;
        let gap15 = f15.series[1].points[0].stats.mean - f15.series[0].best().unwrap().stats.mean;
        assert!(gap13 > 0.0, "fig13: best HomT should beat naive HeMT");
        assert!(
            gap15 < gap13 - 1.0,
            "network bottleneck must close the HomT advantage: {gap13:.1} -> {gap15:.1}"
        );
    }

    #[test]
    fn fig8_converges_within_two_trials() {
        let fig = fig8();
        let pts = &fig.series[0].points;
        let first = pts[0].stats.mean;
        let settled = pts[3].stats.mean;
        assert!(
            settled < first - 5.0,
            "adaptation should cut the map time: {first:.1} -> {settled:.1}"
        );
        // Near the paper's ~60 s optimum once converged.
        assert!((50.0..75.0).contains(&settled), "settled at {settled:.1}");
    }

    #[test]
    fn fig5_rises_with_partition_count() {
        let fig = fig5();
        let pts = &fig.series[0].points;
        let t2 = pts[0].stats.mean;
        let t64 = pts.last().unwrap().stats.mean;
        assert!(
            t64 > t2 * 1.1,
            "network-bound stage time must grow with partitions: {t2:.1} -> {t64:.1}"
        );
    }
}

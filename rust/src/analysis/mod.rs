//! Closed-form results from the paper's analytical sections.
//!
//! * **Claim 1** (Sec. 3): under pull-based assignment of evenly-sized
//!   tasks with constant node speeds, resource idling time (latest minus
//!   earliest node finish time) is bounded by the slowest node's single
//!   task duration. [`claim1_bound`] + the pull simulator used by the
//!   property tests.
//! * **Claim 2** (Sec. 3): two readers of the same HDFS block collide on a
//!   datanode uplink with probability `p1 = 1/r`, readers of different
//!   blocks with `p2 = sum_v P(v) v/r^2 <= p1` (Eqs. (1)–(3)). [`p1`],
//!   [`p2`], [`pv`] regenerate Fig. 4.

use crate::util::math::hypergeom_pv;

/// Eq. (1): probability two readers of the *same* block pick the same
/// datanode: `1/r`.
pub fn p1(r: usize) -> f64 {
    assert!(r >= 1);
    1.0 / r as f64
}

/// Eq. (3): probability that the replica sets of two independently placed
/// blocks overlap in exactly `v` datanodes.
pub fn pv(n: usize, r: usize, v: usize) -> f64 {
    assert!(r >= 1 && r <= n);
    hypergeom_pv(n as u64, r as u64, v as u64)
}

/// Eq. (2): probability two readers of *different* blocks pick the same
/// datanode: `sum_v P(v) * v / r^2`.
pub fn p2(n: usize, r: usize) -> f64 {
    assert!(r >= 1 && r <= n);
    let lo = (2 * r).saturating_sub(n);
    (lo..=r)
        .map(|v| pv(n, r, v) * v as f64 / (r * r) as f64)
        .sum()
}

/// The Fig. 4 series: `(n, p1, p2)` for `n` in `[r, n_max]`.
pub fn fig4_series(r: usize, n_max: usize) -> Vec<(usize, f64, f64)> {
    (r..=n_max).map(|n| (n, p1(r), p2(n, r))).collect()
}

/// Claim 1's bound: with per-node single-task durations `task_secs`, the
/// idle-time bound is the slowest node's task duration.
pub fn claim1_bound(task_secs: &[f64]) -> f64 {
    task_secs.iter().cloned().fold(0.0, f64::max)
}

/// Exact pull-based schedule of `m` equal tasks over nodes with constant
/// `speeds` (tasks/second scale): returns each node's finish time. This is
/// the reference implementation the Claim 1 property test exercises, and
/// the analytic counterpart of the HomT scheduler in the coordinator.
pub fn pull_schedule_finish_times(speeds: &[f64], task_work: f64, m: usize) -> Vec<f64> {
    assert!(!speeds.is_empty());
    assert!(speeds.iter().all(|&s| s > 0.0));
    // Each node pulls its next task the instant it frees up; ties broken
    // by node index (deterministic, matches the driver's dispatch order).
    let n = speeds.len();
    let mut free_at = vec![0.0f64; n];
    for _ in 0..m {
        let i = (0..n)
            .min_by(|&a, &b| {
                free_at[a]
                    .partial_cmp(&free_at[b])
                    .unwrap()
                    .then(a.cmp(&b))
            })
            .unwrap();
        free_at[i] += task_work / speeds[i];
    }
    free_at
}

/// Idle time of a schedule: latest minus earliest node finish time, with
/// nodes that never ran a task finishing at time zero.
pub fn idle_time(finish_times: &[f64]) -> f64 {
    let max = finish_times.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let min = finish_times.iter().cloned().fold(f64::INFINITY, f64::min);
    max - min
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    #[test]
    fn p1_is_one_over_r() {
        assert_eq!(p1(1), 1.0);
        assert_eq!(p1(2), 0.5);
        assert_eq!(p1(4), 0.25);
    }

    #[test]
    fn p2_equals_p1_when_r_equals_n() {
        for n in 1..=8 {
            assert!((p2(n, n) - p1(n)).abs() < 1e-12, "n={n}");
        }
    }

    #[test]
    fn claim2_p1_ge_p2_everywhere() {
        for r in 1..=6 {
            for n in r..=40 {
                assert!(
                    p1(r) >= p2(n, r) - 1e-12,
                    "claim 2 violated at n={n} r={r}: {} < {}",
                    p1(r),
                    p2(n, r)
                );
            }
        }
    }

    #[test]
    fn p2_decreases_with_cluster_size() {
        // Fig. 4's visual: with r fixed, p2 falls as n grows.
        let series = fig4_series(2, 30);
        for w in series.windows(2) {
            assert!(w[1].2 <= w[0].2 + 1e-12, "{w:?}");
        }
        // And approaches r/n^... sanity: p2(30,2) well below p1.
        assert!(series.last().unwrap().2 < 0.1);
    }

    #[test]
    fn p2_closed_form_spot_check() {
        // n=4, r=2: P(0)=C(2,0)C(2,2)/C(4,2)=1/6, P(1)=C(2,1)C(2,1)/6=4/6,
        // P(2)=1/6. p2 = (0*1 + 1*4 + 2*1)/6 / 4 = 0.25.
        assert!((p2(4, 2) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn pull_schedule_small_example() {
        // speeds 1 and 2, four tasks of work 1. Pull order (index ties to
        // the lower node): n0@0 -> busy to 1.0; n1@0 -> 0.5; n1@0.5 -> 1.0;
        // tie at 1.0 -> n0 -> 2.0. Finish times [2.0, 1.0].
        let f = pull_schedule_finish_times(&[1.0, 2.0], 1.0, 4);
        assert!((f[0] - 2.0).abs() < 1e-12);
        assert!((f[1] - 1.0).abs() < 1e-12);
        assert!(idle_time(&f) <= claim1_bound(&[1.0, 0.5]) + 1e-12);
    }

    #[test]
    fn claim1_holds_over_random_instances() {
        // The paper's Claim 1 as a property: idle time <= slowest node's
        // single-task duration, for any speeds and any task count.
        prop::check("claim-1", 0x1D1E, 500, |rng: &mut Rng| {
            let n = rng.range(1, 8);
            let speeds: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 10.0)).collect();
            let m = rng.range(n, 200); // at least one task per node
            let work = rng.range_f64(0.1, 5.0);
            let finish = pull_schedule_finish_times(&speeds, work, m);
            let durations: Vec<f64> = speeds.iter().map(|s| work / s).collect();
            assert!(
                idle_time(&finish) <= claim1_bound(&durations) + 1e-9,
                "idle {} > bound {} (speeds {speeds:?}, m={m})",
                idle_time(&finish),
                claim1_bound(&durations)
            );
        });
    }

    #[test]
    fn more_tasks_reduce_idle_time_on_this_instance() {
        // The HomT motivation: finer partitioning tightens the balance for
        // this heterogeneous pair (not a theorem for all instances, hence
        // a pinned example).
        let speeds = [1.0, 0.4];
        let total_work = 100.0;
        let coarse = {
            let f = pull_schedule_finish_times(&speeds, total_work / 2.0, 2);
            idle_time(&f)
        };
        let fine = {
            let f = pull_schedule_finish_times(&speeds, total_work / 50.0, 50);
            idle_time(&f)
        };
        assert!(fine < coarse, "fine {fine} vs coarse {coarse}");
    }
}

//! Burstable-credit workload planner (Sec. 6.2, Figs. 10–12).
//!
//! A burstable node with credit balance `c` runs at `peak` until the
//! bucket drains — after `c / (peak - baseline)` time units — and at
//! `baseline` thereafter. Its time→work curve `W(t)` is therefore
//! piecewise linear (Fig. 11). To split a job of `w0` work across nodes so
//! they finish together, superpose the curves (Fig. 12), solve
//! `sum_i W_i(t') = w0` on the piecewise-linear sum, and weight each node
//! by `W_i(t')`.
//!
//! Units are free as long as they agree: the paper uses CPU-minutes of
//! work and credit-minutes of balance (1 credit = 1 core-minute).

/// One node's piecewise-linear work curve.
#[derive(Debug, Clone, Copy)]
pub struct CreditCurve {
    /// Speed while credits last (cores).
    pub peak: f64,
    /// Speed once depleted (cores).
    pub baseline: f64,
    /// Current credit balance (core-time units).
    pub credits: f64,
}

impl CreditCurve {
    /// A t2.small-like core with `credits` in CPU-credit *minutes* (the
    /// paper's Fig. 10 parameterization: peak 1, baseline 0.2).
    pub fn t2_small(credits_minutes: f64) -> CreditCurve {
        CreditCurve { peak: 1.0, baseline: 0.2, credits: credits_minutes }
    }

    /// Time at which the bucket drains under full-speed use; infinite if
    /// the node never depletes (peak <= baseline or unlimited credits).
    pub fn deplete_time(&self) -> f64 {
        if self.peak <= self.baseline {
            f64::INFINITY
        } else {
            self.credits / (self.peak - self.baseline)
        }
    }

    /// Work completed by time `t` when running flat out: `W(t)` (Fig. 11).
    pub fn work_by(&self, t: f64) -> f64 {
        assert!(t >= 0.0);
        let td = self.deplete_time();
        if t <= td {
            self.peak * t
        } else {
            self.peak * td + self.baseline * (t - td)
        }
    }

    /// Inverse of [`CreditCurve::work_by`]: the time needed to produce
    /// `w` work. Infinite if `w` is unreachable (zero baseline after
    /// depletion).
    pub fn time_for_work(&self, w: f64) -> f64 {
        assert!(w >= 0.0);
        let td = self.deplete_time();
        let w_peak = if td.is_finite() { self.peak * td } else { f64::INFINITY };
        if w <= w_peak {
            w / self.peak
        } else if self.baseline > 0.0 {
            td + (w - w_peak) / self.baseline
        } else {
            f64::INFINITY
        }
    }
}

/// Result of the Sec. 6.2 planning solve.
#[derive(Debug, Clone, PartialEq)]
pub struct CreditPlan {
    /// The common finish time `t'` with `sum_i W_i(t') = w0`.
    pub t_prime: f64,
    /// Per-node work shares `W_i(t')`; sums to `w0`.
    pub shares: Vec<f64>,
}

impl CreditPlan {
    /// Shares normalized to weights (for [`crate::partition::Partitioning::hemt`]).
    pub fn weights(&self) -> Vec<f64> {
        self.shares.clone()
    }
}

/// Solve the superposed piecewise-linear system `sum_i W_i(t') = w0`
/// (Fig. 12) and return the equalizing shares. Returns `None` if `w0`
/// cannot be met (all nodes depleted with zero baseline).
pub fn plan(curves: &[CreditCurve], w0: f64) -> Option<CreditPlan> {
    assert!(!curves.is_empty());
    assert!(w0 >= 0.0);
    if w0 == 0.0 {
        return Some(CreditPlan { t_prime: 0.0, shares: vec![0.0; curves.len()] });
    }
    // Breakpoints of the superposed curve = every node's depletion time.
    let mut breaks: Vec<f64> = curves
        .iter()
        .map(|c| c.deplete_time())
        .filter(|t| t.is_finite())
        .collect();
    breaks.sort_by(|a, b| a.partial_cmp(b).unwrap());
    breaks.dedup();

    let total_at = |t: f64| -> f64 { curves.iter().map(|c| c.work_by(t)).sum() };
    let slope_at = |t: f64| -> f64 {
        curves
            .iter()
            .map(|c| if t < c.deplete_time() { c.peak } else { c.baseline })
            .sum()
    };

    // Walk segments [prev, next) accumulating work until w0 falls inside.
    let mut prev = 0.0;
    for &b in breaks.iter().chain(std::iter::once(&f64::INFINITY)) {
        let w_prev = total_at(prev);
        let slope = slope_at(prev);
        let seg_end_work = if b.is_finite() { total_at(b) } else { f64::INFINITY };
        if w0 <= seg_end_work + 1e-12 {
            if slope <= 0.0 {
                return None; // flat segment below w0: unreachable
            }
            let t_prime = prev + (w0 - w_prev) / slope;
            let shares = curves.iter().map(|c| c.work_by(t_prime)).collect();
            return Some(CreditPlan { t_prime, shares });
        }
        prev = b;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_t2small_work_in_10_minutes() {
        // Paper: 4 credits -> depletes at 4/(1-0.2) = 5 min; W(10) =
        // 1*5 + 0.2*5 = 6.
        let c = CreditCurve::t2_small(4.0);
        assert!((c.deplete_time() - 5.0).abs() < 1e-12);
        assert!((c.work_by(10.0) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn fig11_inverse_curve() {
        let c = CreditCurve::t2_small(4.0);
        for w in [0.0, 2.0, 5.0, 6.0, 10.0] {
            let t = c.time_for_work(w);
            assert!((c.work_by(t) - w).abs() < 1e-9, "w={w}");
        }
    }

    #[test]
    fn fig12_worked_example() {
        // Paper Sec. 6.2: three nodes with 4, 8, 12 credits; job needs 20
        // CPU-minutes. t' = 80/11; shares {60/11, 80/11, 80/11} ~ {3,4,4}.
        let curves = [
            CreditCurve::t2_small(4.0),
            CreditCurve::t2_small(8.0),
            CreditCurve::t2_small(12.0),
        ];
        let plan = plan(&curves, 20.0).unwrap();
        assert!((plan.t_prime - 80.0 / 11.0).abs() < 1e-9, "t' {}", plan.t_prime);
        let want = [60.0 / 11.0, 80.0 / 11.0, 80.0 / 11.0];
        for (got, want) in plan.shares.iter().zip(want.iter()) {
            assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        }
        // Ratio 3:4:4 as the paper states.
        let k = plan.shares[0] / 3.0;
        assert!((plan.shares[1] - 4.0 * k).abs() < 1e-9);
        assert!((plan.shares[2] - 4.0 * k).abs() < 1e-9);
    }

    #[test]
    fn homogeneous_nodes_split_evenly() {
        let curves = [CreditCurve::t2_small(10.0); 4];
        let p = plan(&curves, 8.0).unwrap();
        for s in &p.shares {
            assert!((s - 2.0).abs() < 1e-9);
        }
    }

    #[test]
    fn all_work_within_burst_needs_no_baseline() {
        // w0 small enough that no one depletes: proportional to peak.
        let curves = [
            CreditCurve { peak: 1.0, baseline: 0.0, credits: 100.0 },
            CreditCurve { peak: 0.5, baseline: 0.0, credits: 100.0 },
        ];
        let p = plan(&curves, 3.0).unwrap();
        assert!((p.shares[0] - 2.0).abs() < 1e-9);
        assert!((p.shares[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn unreachable_work_returns_none() {
        // Zero baseline, tiny credits: only 1 unit of work ever possible.
        let curves = [CreditCurve { peak: 1.0, baseline: 0.0, credits: 1.0 }];
        assert!(plan(&curves, 2.0).is_none());
        assert!(plan(&curves, 0.5).is_some());
    }

    #[test]
    fn shares_sum_to_w0_and_finish_simultaneously() {
        use crate::util::{prop, Rng};
        prop::check("credit-plan", 0xC4ED, 300, |rng: &mut Rng| {
            let n = rng.range(1, 6);
            let curves: Vec<CreditCurve> = (0..n)
                .map(|_| CreditCurve {
                    peak: rng.range_f64(0.5, 2.0),
                    baseline: rng.range_f64(0.05, 0.4),
                    credits: rng.range_f64(0.0, 20.0),
                })
                .collect();
            let w0 = rng.range_f64(0.1, 50.0);
            let p = plan(&curves, w0).expect("positive baselines: solvable");
            let total: f64 = p.shares.iter().sum();
            assert!((total - w0).abs() < 1e-6, "shares sum {total} != {w0}");
            // Equal finish time: every node completes its share at t'.
            for (c, s) in curves.iter().zip(p.shares.iter()) {
                if *s > 1e-9 {
                    assert!(
                        (c.time_for_work(*s) - p.t_prime).abs() < 1e-6,
                        "node finishes at {} != t' {}",
                        c.time_for_work(*s),
                        p.t_prime
                    );
                }
            }
        });
    }
}

//! Supply-side characterization: the paper's executor-speed learners.
//!
//! * [`SpeedEstimator`] — OA-HeMT (Sec. 5.1): per-(job-type, executor)
//!   speed estimates updated with the first-order autoregressive filter
//!   `v_i <- (1-alpha) d_i/t_i + alpha v_i`; cold-start executors get the
//!   mean of known speeds; the first job of a type is split evenly.
//! * [`credits`] — the burstable-credit workload planner of Sec. 6.2
//!   (Figs. 10–12): piecewise-linear time→work curves and their
//!   superposition solve.
//! * [`probe_weights`] — the Sec. 6.2 "fudge factor" learner: short trial
//!   tasks measure effective speed directly, correcting nominal
//!   peak/baseline ratios (1:0.4 -> 1:0.32) for cache/TLB contention.

pub mod credits;

use std::collections::BTreeMap;

/// OA-HeMT first-order autoregressive executor-speed estimator. One
/// instance per job type (the paper: "each application framework will
/// need to maintain its own estimates").
///
/// Beyond the paper's point estimates, the estimator tracks a *posterior
/// dispersion* per executor: the same AR(1) filter applied to squared
/// relative innovations (`((sample - old) / old)^2`). [`rel_std`]
/// surfaces it as a relative standard deviation — the confidence signal
/// the granularity controller
/// ([`crate::coordinator::granularity`]) coarsens or hedges on.
///
/// [`rel_std`]: SpeedEstimator::rel_std
#[derive(Debug, Clone)]
pub struct SpeedEstimator {
    /// Forgetting factor in [0, 1): weight on the *old* estimate. 0 means
    /// "latest observation only" (the paper's Fig. 7 setting).
    pub alpha: f64,
    speeds: BTreeMap<usize, f64>,
    /// Smoothed squared relative innovation per executor. Absent until
    /// an executor's *second* observation — one sample carries no
    /// dispersion information.
    rel_vars: BTreeMap<usize, f64>,
}

impl SpeedEstimator {
    pub fn new(alpha: f64) -> SpeedEstimator {
        assert!((0.0..1.0).contains(&alpha), "alpha must be in [0,1)");
        SpeedEstimator { alpha, speeds: BTreeMap::new(), rel_vars: BTreeMap::new() }
    }

    /// Record an observed task: executor `id` processed `d` bytes in `t`
    /// seconds. First observation seeds the estimate directly.
    pub fn observe(&mut self, id: usize, d: f64, t: f64) {
        assert!(d > 0.0 && t > 0.0, "need positive work and time");
        let sample = d / t;
        let v = match self.speeds.get(&id) {
            Some(&old) => {
                // Innovation relative to the standing estimate (old > 0
                // because every sample is a positive rate), smoothed with
                // the same forgetting factor as the mean.
                let e = (sample - old) / old;
                let var = match self.rel_vars.get(&id) {
                    Some(&w) => (1.0 - self.alpha) * e * e + self.alpha * w,
                    None => e * e,
                };
                self.rel_vars.insert(id, var);
                (1.0 - self.alpha) * sample + self.alpha * old
            }
            None => sample,
        };
        self.speeds.insert(id, v);
    }

    /// Relative posterior standard deviation of one executor's speed
    /// estimate (`None` until two observations). ~0 means the executor's
    /// samples keep confirming the estimate; ~1 means samples swing by
    /// the estimate's own magnitude.
    pub fn rel_std(&self, id: usize) -> Option<f64> {
        self.rel_vars.get(&id).map(|v| v.sqrt())
    }

    /// Current estimate for one executor, if any.
    pub fn speed(&self, id: usize) -> Option<f64> {
        self.speeds.get(&id).copied()
    }

    pub fn is_cold(&self) -> bool {
        self.speeds.is_empty()
    }

    /// Partition weights for the given executor set (Sec. 5.1): known
    /// executors use their estimate; unseen executors get the mean of the
    /// known ones (`v̄`); a fully cold estimator yields even weights (the
    /// paper's k=1 bootstrap).
    pub fn weights(&self, executors: &[usize]) -> Vec<f64> {
        assert!(!executors.is_empty());
        let known: Vec<f64> = executors
            .iter()
            .filter_map(|id| self.speeds.get(id).copied())
            .collect();
        if known.is_empty() {
            return vec![1.0; executors.len()];
        }
        let mean = known.iter().sum::<f64>() / known.len() as f64;
        executors
            .iter()
            .map(|id| self.speeds.get(id).copied().unwrap_or(mean))
            .collect()
    }
}

/// Probe-based weights (the Sec. 6.2 fudge-factor learner): run a short
/// equal-sized trial task on every executor, measure `(bytes, secs)`, and
/// return speeds normalized to the fastest executor — directly usable as
/// HeMT weights and comparable to nominal peak/baseline ratios.
pub fn probe_weights(observations: &[(f64, f64)]) -> Vec<f64> {
    assert!(!observations.is_empty());
    let rates: Vec<f64> = observations
        .iter()
        .map(|&(d, t)| {
            assert!(d > 0.0 && t > 0.0);
            d / t
        })
        .collect();
    let max = rates.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    rates.iter().map(|r| r / max).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_estimator_gives_even_weights() {
        let e = SpeedEstimator::new(0.0);
        assert!(e.is_cold());
        assert_eq!(e.weights(&[0, 1, 2]), vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn first_observation_seeds_directly() {
        let mut e = SpeedEstimator::new(0.5);
        e.observe(0, 100.0, 10.0);
        assert_eq!(e.speed(0), Some(10.0));
    }

    #[test]
    fn alpha_zero_tracks_latest_sample() {
        let mut e = SpeedEstimator::new(0.0);
        e.observe(0, 100.0, 10.0);
        e.observe(0, 100.0, 50.0); // slowed to 2 B/s
        assert_eq!(e.speed(0), Some(2.0));
    }

    #[test]
    fn alpha_blends_old_and_new() {
        let mut e = SpeedEstimator::new(0.25);
        e.observe(0, 100.0, 10.0); // 10
        e.observe(0, 100.0, 50.0); // 0.75*2 + 0.25*10 = 4
        assert!((e.speed(0).unwrap() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn unseen_executor_gets_mean_of_known() {
        let mut e = SpeedEstimator::new(0.0);
        e.observe(0, 100.0, 10.0); // 10
        e.observe(1, 100.0, 5.0); // 20
        let w = e.weights(&[0, 1, 2]);
        assert_eq!(w, vec![10.0, 20.0, 15.0]);
    }

    #[test]
    fn weights_converge_to_true_speeds_under_noise() {
        use crate::util::Rng;
        // Executors at true speeds 1.0 and 0.4 with 5% noise; alpha=0.5.
        let mut rng = Rng::new(17);
        let mut e = SpeedEstimator::new(0.5);
        for _ in 0..50 {
            for (id, s) in [(0usize, 1.0f64), (1, 0.4)] {
                let t = 100.0 / (s * (1.0 + 0.05 * rng.normal()));
                e.observe(id, 100.0, t);
            }
        }
        let w = e.weights(&[0, 1]);
        let ratio = w[1] / w[0];
        assert!((ratio - 0.4).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn probe_weights_recover_effective_ratio() {
        // The paper's measured 1 : 0.32 despite the nominal 1 : 0.4.
        let w = probe_weights(&[(64.0, 10.0), (64.0, 31.25)]);
        assert!((w[0] - 1.0).abs() < 1e-12);
        assert!((w[1] - 0.32).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "alpha must be in [0,1)")]
    fn alpha_one_rejected() {
        SpeedEstimator::new(1.0);
    }
}

//! `hemt` — the HeMT reproduction CLI (leader entrypoint).
//!
//! Every simulation subcommand is a thin translator onto the unified
//! [`hemt::api::RunRequest`] surface: flags parse into a request, the
//! request runs through [`hemt::api::execute_with`], and a rendering
//! callback prints banners/tables exactly where the historic
//! per-subcommand plumbing did (asserted bit-identical by
//! `rust/tests/api_golden.rs`). The same requests drive `hemt request
//! <file.json>` and the `hemt serve` HTTP service.
//!
//! Subcommands:
//!
//! * `hemt figure <4|5|7|8|9|10|13|14|15|17|18|headline|all> [--json]` —
//!   regenerate a paper figure on the simulation substrate and print the
//!   paper-shaped table (or JSON). `--list` prints the figure registry.
//! * `hemt run --config <file.json> [--json]` — run a custom experiment
//!   described by an [`hemt::config::ExperimentConfig`].
//! * `hemt dynamics [--rounds N]` — closed-loop Adaptive-HeMT vs
//!   static-HeMT vs HomT under time-varying node capacity
//!   ([`hemt::dynamics`]).
//! * `hemt request <file.json>` — run any serialized
//!   [`hemt::api::RunRequest`].
//! * `hemt serve` — the persistent sweep service ([`hemt::serve`]).
//! * `hemt analysis` — print the closed-form Claim 1 / Claim 2 numbers.
//! * `hemt plan-credits --work <W> <credits...>` — the Sec. 6.2 burstable
//!   credit planner: split `W` CPU-minutes across t2.small-like nodes.
//! * `hemt real <wordcount|kmeans|pagerank>` — run the workload for real
//!   through the PJRT artifacts on a throttled heterogeneous pool
//!   (requires `make artifacts`).
//! * `hemt artifacts` — list the loaded AOT artifacts.

use std::process::ExitCode;

use hemt::api::{self, RunEvent, RunRequest};
use hemt::estimator::credits::{plan, CreditCurve};
use hemt::{analysis, config, experiments};

fn usage() -> &'static str {
    "usage:
  hemt figure <id|all> [--json] [--threads N]
                                    reproduce a paper figure (4,5,7,8,9,10,13,14,15,17,18,headline)
  hemt figure --list [--json]       list the figure registry (name, description,
                                    and the RunRequest JSON that reproduces it)
  hemt ablation <name|all> [--json] [--threads N]
                                    design-choice ablations (alpha, speculation, rack, stale_credits)
  hemt run --config <file> [--json] [--threads N]
                                    run an experiment config
  hemt sweep [--config <file>] [--preset <tiny_tasks|dynamics|cluster_scale>] [--json] [--threads N]
                                    whole-grid product sweep (dynamics x clusters x
                                    workloads x policies x granularities); default:
                                    the built-in tiny-tasks regime product
  hemt dynamics [--correlated|--auto] [--rounds N] [--json] [--threads N]
                                    closed-loop Adaptive-HeMT vs static-HeMT vs HomT
                                    under time-varying capacity (Markov throttling,
                                    spot outage, diurnal, credit cliff).
                                    --correlated runs the correlated figures instead:
                                    rack_steal (shared-event rack degradation, thieves
                                    degrade with victims) and link_degrade (time-varying
                                    HDFS uplink capacity on the 200 Mbps testbed).
                                    --auto runs the granularity-controller figures:
                                    auto_granularity (the online controller picking
                                    arm + task granularity per round vs every fixed
                                    policy) and the headline controller_grid (same
                                    arms across all compute-bound dynamics families)
  hemt steal [--streams] [--rounds N] [--json] [--threads N]
                                    mid-stage work stealing: Steal-HeMT (running
                                    tasks split, remainder re-homed on idle nodes)
                                    vs Adaptive-HeMT vs static-HeMT vs HomT across
                                    the same capacity-program families. --streams
                                    runs the network-bound comparison instead:
                                    stream-splitting stealing (in-flight reads
                                    re-issued from a different replica) vs
                                    CPU-only stealing under spot/markov dynamics
  hemt request <file.json> [--json] [--threads N]
                                    run a serialized RunRequest (the same JSON
                                    document `hemt serve` accepts on POST /run)
  hemt trace <file.json> [--out trace.json]
                                    run a serialized RunRequest serially with the
                                    span recorder on: writes Chrome trace-event
                                    JSON (load in Perfetto / chrome://tracing)
                                    and prints the per-stage compute/overhead/idle
                                    breakdown per policy arm. Figures are
                                    bit-identical to the untraced run
  hemt serve [--addr H:P] [--workers N] [--queue N] [--threads N]
             [--memo-entries N] [--memo-bytes N]
                                    persistent sweep service: POST /run streams
                                    per-trial results over SSE (?trace=1 adds
                                    span frames); results are memoized by spec
                                    hash (bounded LRU: --memo-entries /
                                    --memo-bytes) and sessions pooled per
                                    cluster. GET /figures, GET /metrics (JSON,
                                    or Prometheus text via Accept: text/plain),
                                    GET /healthz, POST /shutdown
  hemt bench-diff --baseline <dir> --new <dir> [--threshold F] [--update]
                                    diff BENCH_*.json medians against a committed
                                    baseline; exit 1 past the threshold (default 0.15)
  hemt analysis                     closed-form Claim 1 / Claim 2 numbers
  hemt plan-credits --work <W> <c1> <c2> ...   burstable credit planner
  hemt real <wordcount|kmeans|pagerank>        real PJRT execution demo
  hemt artifacts                    list AOT artifacts

  Sweeps fan trials out over a worker pool: --threads (or the
  HEMT_SWEEP_THREADS env var) sets the pool size, defaulting to the
  machine's available parallelism. Results are bit-identical for any
  thread count.

  Full command reference with copy-pasteable examples: docs/CLI.md"
}

/// Parse `--threads N` into a sweep runner (default: env/auto).
fn runner_from_args(args: &[String]) -> Result<hemt::sweep::SweepRunner, String> {
    match args.iter().position(|a| a == "--threads") {
        None => Ok(hemt::experiments::default_runner()),
        Some(i) => {
            let n: usize = args
                .get(i + 1)
                .ok_or("--threads needs a value")?
                .parse()
                .map_err(|e| format!("bad --threads: {e}"))?;
            if n == 0 {
                return Err("--threads must be >= 1".into());
            }
            Ok(hemt::sweep::SweepRunner::new(n))
        }
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(String::as_str) {
        Some("figure") => cmd_figure(&args[1..]),
        Some("ablation") => cmd_ablation(&args[1..]),
        Some("run") => cmd_run(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("dynamics") => cmd_dynamics(&args[1..]),
        Some("steal") => cmd_steal(&args[1..]),
        Some("request") => cmd_request(&args[1..]),
        Some("trace") => cmd_trace(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("bench-diff") => cmd_bench_diff(&args[1..]),
        Some("analysis") => cmd_analysis(),
        Some("plan-credits") => cmd_plan_credits(&args[1..]),
        Some("real") => cmd_real(&args[1..]),
        Some("artifacts") => cmd_artifacts(),
        Some("--help") | Some("-h") | None => {
            println!("{}", usage());
            Ok(())
        }
        Some(other) => Err(format!("unknown command '{other}'\n{}", usage())),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

/// First positional argument, skipping flags and their values.
fn positional(args: &[String]) -> Option<&String> {
    let mut skip_next = false;
    for a in args {
        if skip_next {
            skip_next = false;
            continue;
        }
        if a == "--threads"
            || a == "--config"
            || a == "--preset"
            || a == "--rounds"
            || a == "--addr"
            || a == "--workers"
            || a == "--queue"
            || a == "--out"
        {
            skip_next = true;
            continue;
        }
        if a.starts_with("--") {
            continue;
        }
        return Some(a);
    }
    None
}

/// The value following `flag`, if the flag is present.
fn flag_value<'a>(args: &'a [String], flag: &str) -> Result<Option<&'a String>, String> {
    match args.iter().position(|a| a == flag) {
        None => Ok(None),
        Some(i) => args
            .get(i + 1)
            .map(Some)
            .ok_or_else(|| format!("{flag} needs a value")),
    }
}

/// Run a request and render it the way the historic subcommands did:
/// non-empty banners to stderr before compute, then per output either
/// the figure JSON (`--json`) or the table plus the per-family winners
/// block. Printing happens on `Output` events, so multi-output requests
/// (`figure all`, `dynamics --correlated`) interleave banners and
/// tables exactly as before.
fn run_request(req: &RunRequest, args: &[String]) -> Result<(), String> {
    let json = args.iter().any(|a| a == "--json");
    let runner = runner_from_args(args)?;
    api::execute_with(req, &runner, |ev| match ev {
        RunEvent::Start { banner, .. } => {
            if !banner.is_empty() {
                eprintln!("{banner}");
            }
        }
        RunEvent::Unit { .. } => {}
        RunEvent::Output { output, .. } => {
            if json {
                println!("{}", output.figure.to_json().pretty());
            } else {
                println!("{}", output.figure.to_table());
                if let Some(winners) = output.winners_table() {
                    println!("{winners}");
                }
            }
        }
    })?;
    Ok(())
}

fn cmd_figure(args: &[String]) -> Result<(), String> {
    if args.iter().any(|a| a == "--list") {
        if args.iter().any(|a| a == "--json") {
            println!("{}", api::figure_registry_json().pretty());
        } else {
            for f in experiments::FIGURES {
                println!("{:<13} {}", f.name, f.description);
            }
        }
        return Ok(());
    }
    let name = positional(args).ok_or("figure id required")?;
    run_request(&RunRequest::Figure { name: name.clone() }, args)
}

fn cmd_ablation(args: &[String]) -> Result<(), String> {
    let name = positional(args).ok_or("ablation name required")?;
    run_request(&RunRequest::Ablation { name: name.clone() }, args)
}

fn cmd_run(args: &[String]) -> Result<(), String> {
    let path = flag_value(args, "--config")?.ok_or("--config <file> required")?;
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let cfg = config::ExperimentConfig::from_str(&text)?;
    run_request(&RunRequest::Sweep { config: cfg }, args)
}

/// `hemt sweep`: run a whole-grid scenario product (the built-in
/// tiny-tasks regime product, or a JSON `ProductSweepSpec` via
/// `--config`) through the sweep runner.
fn cmd_sweep(args: &[String]) -> Result<(), String> {
    let product = match flag_value(args, "--config")? {
        Some(path) => {
            let text =
                std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
            hemt::sweep::ProductSweepSpec::from_str(&text)?
        }
        None => match args.iter().position(|a| a == "--preset") {
            None => hemt::sweep::ProductSweepSpec::tiny_tasks_regimes(),
            Some(i) => match args.get(i + 1).map(String::as_str) {
                Some("tiny_tasks") => hemt::sweep::ProductSweepSpec::tiny_tasks_regimes(),
                Some("dynamics") => hemt::sweep::ProductSweepSpec::dynamic_regimes(),
                Some("cluster_scale") => hemt::sweep::ProductSweepSpec::cluster_scale_regimes(),
                Some(other) => {
                    return Err(format!(
                        "unknown preset '{other}' (expected tiny_tasks, dynamics, or cluster_scale)"
                    ))
                }
                None => return Err("--preset needs a value".into()),
            },
        },
    };
    run_request(&RunRequest::ProductSweep { spec: product }, args)
}

/// `hemt dynamics`: the closed-loop comparison — Adaptive-HeMT (the
/// OA estimator loop re-partitioning between rounds) vs static-HeMT
/// (weights frozen at launch hints) vs HomT, across the capacity-program
/// families (Markov throttling, spot outage, diurnal interference,
/// credit cliff). All three arms of a family share one seed, hence one
/// capacity trace; output is bit-identical for any thread count.
///
/// With `--correlated`, the correlated-dynamics figures instead: the
/// `rack_steal` comparison (the steal arm set under rack-wide
/// shared-event degradation, where thieves degrade with victims) then
/// the `link_degrade` comparison (HeMT vs HomT on the 200 Mbps
/// read-heavy testbed with the datanode uplinks themselves
/// time-varying).
///
/// With `--auto`, the granularity-controller figures instead: the
/// `auto_granularity` comparison (the online controller
/// [`hemt::coordinator::granularity`] vs all four fixed arms on the
/// historic families and seeds) then the headline `controller_grid`
/// (the same arms across every compute-bound dynamics family,
/// rack-correlated included).
fn cmd_dynamics(args: &[String]) -> Result<(), String> {
    let req = RunRequest::Dynamics {
        correlated: args.iter().any(|a| a == "--correlated"),
        auto: args.iter().any(|a| a == "--auto"),
        rounds: rounds_arg(args)?,
    };
    run_request(&req, args)
}

/// `hemt steal`: the mid-stage work-stealing comparison — Steal-HeMT
/// (running tasks split on capacity events / idle nodes, the carved
/// remainder re-homed — [`hemt::coordinator::stealing`]) vs
/// Adaptive-HeMT vs static-HeMT vs HomT across the capacity-program
/// families. With `--streams`, the network-bound `net_steal` comparison
/// instead: stream-splitting stealing (in-flight reads truncated, the
/// unread range re-issued from a different replica) head-to-head with
/// CPU-only stealing. All arms of a family share one seed, hence one
/// capacity trace; output is bit-identical for any thread count.
fn cmd_steal(args: &[String]) -> Result<(), String> {
    let req = RunRequest::Steal {
        streams: args.iter().any(|a| a == "--streams"),
        rounds: rounds_arg(args)?,
    };
    run_request(&req, args)
}

/// `hemt request`: run any serialized [`RunRequest`] — the same JSON
/// document `hemt serve` accepts on `POST /run`.
fn cmd_request(args: &[String]) -> Result<(), String> {
    let path = positional(args).ok_or("request file required (a RunRequest JSON document)")?;
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let req = RunRequest::from_str(&text)?;
    run_request(&req, args)
}

/// `hemt trace`: run a serialized [`RunRequest`] with the span recorder
/// installed ([`hemt::obs`]) — serial execution, figures bit-identical
/// to the untraced run. Writes Chrome trace-event JSON to `--out`
/// (default `trace.json`; load in Perfetto or chrome://tracing) and
/// prints the per-stage compute/overhead/idle breakdown to stdout.
fn cmd_trace(args: &[String]) -> Result<(), String> {
    let path = positional(args).ok_or("request file required (a RunRequest JSON document)")?;
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let req = RunRequest::from_str(&text)?;
    let out_path = flag_value(args, "--out")?
        .map(String::as_str)
        .unwrap_or("trace.json");
    let (_result, rec) = api::execute_traced(&req, |ev| {
        if let RunEvent::Start { banner, .. } = ev {
            if !banner.is_empty() {
                eprintln!("{banner}");
            }
        }
    })?;
    let trace = hemt::obs::chrome_trace(&rec);
    std::fs::write(out_path, trace.pretty())
        .map_err(|e| format!("writing {out_path}: {e}"))?;
    print!("{}", hemt::obs::breakdown(&rec));
    eprintln!("wrote {out_path}");
    Ok(())
}

/// `hemt serve`: the persistent sweep service ([`hemt::serve`]).
fn cmd_serve(args: &[String]) -> Result<(), String> {
    let mut cfg = hemt::serve::ServeConfig::default();
    if let Some(addr) = flag_value(args, "--addr")? {
        cfg.addr = addr.clone();
    }
    if let Some(w) = flag_value(args, "--workers")? {
        cfg.workers = w.parse().map_err(|e| format!("bad --workers: {e}"))?;
        if cfg.workers == 0 {
            return Err("--workers must be >= 1".into());
        }
    }
    if let Some(q) = flag_value(args, "--queue")? {
        cfg.max_queue = q.parse().map_err(|e| format!("bad --queue: {e}"))?;
        if cfg.max_queue == 0 {
            return Err("--queue must be >= 1".into());
        }
    }
    if let Some(t) = flag_value(args, "--threads")? {
        // 0 = environment default, matching ServeConfig semantics.
        cfg.threads = t.parse().map_err(|e| format!("bad --threads: {e}"))?;
    }
    if let Some(n) = flag_value(args, "--memo-entries")? {
        // 0 is allowed: completed runs are evicted immediately (memo off).
        cfg.memo_entries = n.parse().map_err(|e| format!("bad --memo-entries: {e}"))?;
    }
    if let Some(b) = flag_value(args, "--memo-bytes")? {
        cfg.memo_bytes = b.parse().map_err(|e| format!("bad --memo-bytes: {e}"))?;
    }
    let addr = cfg.addr.clone();
    let workers = cfg.workers;
    let max_queue = cfg.max_queue;
    let handle = hemt::serve::spawn(cfg).map_err(|e| format!("binding {addr}: {e}"))?;
    eprintln!(
        "hemt serve: listening on {} ({workers} worker(s), queue {max_queue}); \
         POST /run streams SSE; GET /figures, GET /metrics, GET /healthz, POST /shutdown",
        handle.addr()
    );
    handle.join();
    eprintln!("hemt serve: drained");
    Ok(())
}

/// Parse `--rounds N` (default: the dynamics comparison's round count).
fn rounds_arg(args: &[String]) -> Result<usize, String> {
    match args.iter().position(|a| a == "--rounds") {
        None => Ok(hemt::dynamics::DEFAULT_ROUNDS),
        Some(i) => {
            let n: usize = args
                .get(i + 1)
                .ok_or("--rounds needs a value")?
                .parse()
                .map_err(|e| format!("bad --rounds: {e}"))?;
            if n == 0 {
                return Err("--rounds must be >= 1".into());
            }
            Ok(n)
        }
    }
}

/// `hemt bench-diff`: the CI bench-trajectory gate. Compares medians of
/// `BENCH_*.json` files in `--new` against `--baseline`; exits non-zero
/// when any bench regressed past the threshold or went missing.
fn cmd_bench_diff(args: &[String]) -> Result<(), String> {
    use hemt::bench_harness as bh;
    let dir_arg = |flag: &str| -> Result<std::path::PathBuf, String> {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .map(std::path::PathBuf::from)
            .ok_or_else(|| format!("{flag} <dir> required"))
    };
    let baseline = dir_arg("--baseline")?;
    let new = dir_arg("--new")?;
    let threshold: f64 = match args.iter().position(|a| a == "--threshold") {
        None => 0.15,
        Some(i) => args
            .get(i + 1)
            .ok_or("--threshold needs a value")?
            .parse()
            .map_err(|e| format!("bad --threshold: {e}"))?,
    };
    if args.iter().any(|a| a == "--update") {
        let copied = bh::update_baselines(&baseline, &new)?;
        println!("updated {} baseline report(s) in {}:", copied.len(), baseline.display());
        for name in copied {
            println!("  {name}");
        }
        return Ok(());
    }
    let report = bh::compare_bench_dirs(&baseline, &new, threshold)?;
    if report.is_empty() {
        println!(
            "bench-diff: no BENCH_*.json in {} or {} — nothing to gate",
            baseline.display(),
            new.display()
        );
        return Ok(());
    }
    print!("{}", bh::trajectory_table(&report, threshold));
    if bh::trajectory_passes(&report) {
        println!("bench trajectory: OK");
        Ok(())
    } else {
        Err(format!(
            "bench trajectory gate failed (>{:.0}% median regression or missing bench); \
             refresh intentionally with `hemt bench-diff --baseline {} --new {} --update`",
            threshold * 100.0,
            baseline.display(),
            new.display()
        ))
    }
}

fn cmd_analysis() -> Result<(), String> {
    println!("Claim 2 (Sec. 3): same-datanode collision probabilities");
    println!("{:>4} {:>4} {:>10} {:>10}", "n", "r", "p1", "p2");
    for r in [2usize, 3] {
        for n in [r, 4, 8, 16, 30] {
            if n >= r {
                println!(
                    "{:>4} {:>4} {:>10.4} {:>10.4}",
                    n,
                    r,
                    analysis::p1(r),
                    analysis::p2(n, r)
                );
            }
        }
    }
    println!();
    println!("Claim 1 (Sec. 3): pull-based idle-time bound demo (speeds 1.0/0.4)");
    for m in [2usize, 8, 32] {
        let f = analysis::pull_schedule_finish_times(&[1.0, 0.4], 100.0 / m as f64, m);
        println!(
            "  m={m:>3}: idle {:>7.2} s <= bound {:>7.2} s",
            analysis::idle_time(&f),
            analysis::claim1_bound(&[100.0 / m as f64 / 1.0, 100.0 / m as f64 / 0.4])
        );
    }
    Ok(())
}

fn cmd_plan_credits(args: &[String]) -> Result<(), String> {
    let work_pos = args
        .iter()
        .position(|a| a == "--work")
        .ok_or("--work <cpu-minutes> required")?;
    let work: f64 = args
        .get(work_pos + 1)
        .ok_or("--work needs a value")?
        .parse()
        .map_err(|e| format!("bad --work: {e}"))?;
    let credits: Vec<f64> = args
        .iter()
        .enumerate()
        .filter(|&(i, a)| !a.starts_with("--") && i != work_pos + 1)
        .map(|(_, a)| a.parse().map_err(|e| format!("bad credit value '{a}': {e}")))
        .collect::<Result<_, _>>()?;
    if credits.is_empty() {
        return Err("need at least one node's credit balance".into());
    }
    let curves: Vec<CreditCurve> = credits.iter().map(|&c| CreditCurve::t2_small(c)).collect();
    let p = plan(&curves, work).ok_or("workload unreachable with these curves")?;
    println!("t' = {:.4} minutes (all nodes finish simultaneously)", p.t_prime);
    for (i, (c, share)) in credits.iter().zip(p.shares.iter()).enumerate() {
        println!(
            "  node {i}: credits {c:>6.2} -> share {share:>8.4} CPU-min ({:.1}%)",
            100.0 * share / work
        );
    }
    Ok(())
}

fn cmd_real(args: &[String]) -> Result<(), String> {
    let wl = args.first().ok_or("workload required: wordcount|kmeans|pagerank")?;
    hemt::exec::demo::run_demo(wl).map_err(|e| format!("{e:#}"))
}

fn cmd_artifacts() -> Result<(), String> {
    let rt = hemt::runtime::Runtime::load_default().map_err(|e| format!("{e:#}"))?;
    println!("platform: {}", rt.platform());
    println!("artifacts dir: {}", rt.artifacts_dir().display());
    for name in rt.artifact_names() {
        println!("  {name}");
    }
    Ok(())
}

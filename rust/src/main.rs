//! `hemt` — the HeMT reproduction CLI (leader entrypoint).
//!
//! Subcommands:
//!
//! * `hemt figure <4|5|7|8|9|10|13|14|15|17|18|headline|all> [--json]` —
//!   regenerate a paper figure on the simulation substrate and print the
//!   paper-shaped table (or JSON).
//! * `hemt run --config <file.json> [--json]` — run a custom experiment
//!   described by an [`hemt::config::ExperimentConfig`].
//! * `hemt dynamics [--rounds N]` — closed-loop Adaptive-HeMT vs
//!   static-HeMT vs HomT under time-varying node capacity
//!   ([`hemt::dynamics`]).
//! * `hemt analysis` — print the closed-form Claim 1 / Claim 2 numbers.
//! * `hemt plan-credits --work <W> <credits...>` — the Sec. 6.2 burstable
//!   credit planner: split `W` CPU-minutes across t2.small-like nodes.
//! * `hemt real <wordcount|kmeans|pagerank>` — run the workload for real
//!   through the PJRT artifacts on a throttled heterogeneous pool
//!   (requires `make artifacts`).
//! * `hemt artifacts` — list the loaded AOT artifacts.

use std::process::ExitCode;

use hemt::estimator::credits::{plan, CreditCurve};
use hemt::{analysis, config, experiments};

fn usage() -> &'static str {
    "usage:
  hemt figure <id|all> [--json] [--threads N]
                                    reproduce a paper figure (4,5,7,8,9,10,13,14,15,17,18,headline)
  hemt ablation <name|all> [--json] [--threads N]
                                    design-choice ablations (alpha, speculation, rack, stale_credits)
  hemt run --config <file> [--json] [--threads N]
                                    run an experiment config
  hemt sweep [--config <file>] [--preset <tiny_tasks|dynamics>] [--json] [--threads N]
                                    whole-grid product sweep (dynamics x clusters x
                                    workloads x policies x granularities); default:
                                    the built-in tiny-tasks regime product
  hemt dynamics [--correlated] [--rounds N] [--json] [--threads N]
                                    closed-loop Adaptive-HeMT vs static-HeMT vs HomT
                                    under time-varying capacity (Markov throttling,
                                    spot outage, diurnal, credit cliff).
                                    --correlated runs the correlated figures instead:
                                    rack_steal (shared-event rack degradation, thieves
                                    degrade with victims) and link_degrade (time-varying
                                    HDFS uplink capacity on the 200 Mbps testbed)
  hemt steal [--streams] [--rounds N] [--json] [--threads N]
                                    mid-stage work stealing: Steal-HeMT (running
                                    tasks split, remainder re-homed on idle nodes)
                                    vs Adaptive-HeMT vs static-HeMT vs HomT across
                                    the same capacity-program families. --streams
                                    runs the network-bound comparison instead:
                                    stream-splitting stealing (in-flight reads
                                    re-issued from a different replica) vs
                                    CPU-only stealing under spot/markov dynamics
  hemt bench-diff --baseline <dir> --new <dir> [--threshold F] [--update]
                                    diff BENCH_*.json medians against a committed
                                    baseline; exit 1 past the threshold (default 0.15)
  hemt analysis                     closed-form Claim 1 / Claim 2 numbers
  hemt plan-credits --work <W> <c1> <c2> ...   burstable credit planner
  hemt real <wordcount|kmeans|pagerank>        real PJRT execution demo
  hemt artifacts                    list AOT artifacts

  Sweeps fan trials out over a worker pool: --threads (or the
  HEMT_SWEEP_THREADS env var) sets the pool size, defaulting to the
  machine's available parallelism. Results are bit-identical for any
  thread count."
}

/// Parse `--threads N` into a sweep runner (default: env/auto).
fn runner_from_args(args: &[String]) -> Result<hemt::sweep::SweepRunner, String> {
    match args.iter().position(|a| a == "--threads") {
        None => Ok(hemt::experiments::default_runner()),
        Some(i) => {
            let n: usize = args
                .get(i + 1)
                .ok_or("--threads needs a value")?
                .parse()
                .map_err(|e| format!("bad --threads: {e}"))?;
            if n == 0 {
                return Err("--threads must be >= 1".into());
            }
            Ok(hemt::sweep::SweepRunner::new(n))
        }
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(String::as_str) {
        Some("figure") => cmd_figure(&args[1..]),
        Some("ablation") => cmd_ablation(&args[1..]),
        Some("run") => cmd_run(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("dynamics") => cmd_dynamics(&args[1..]),
        Some("steal") => cmd_steal(&args[1..]),
        Some("bench-diff") => cmd_bench_diff(&args[1..]),
        Some("analysis") => cmd_analysis(),
        Some("plan-credits") => cmd_plan_credits(&args[1..]),
        Some("real") => cmd_real(&args[1..]),
        Some("artifacts") => cmd_artifacts(),
        Some("--help") | Some("-h") | None => {
            println!("{}", usage());
            Ok(())
        }
        Some(other) => Err(format!("unknown command '{other}'\n{}", usage())),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

/// First positional argument, skipping flags and their values.
fn positional(args: &[String]) -> Option<&String> {
    let mut skip_next = false;
    for a in args {
        if skip_next {
            skip_next = false;
            continue;
        }
        if a == "--threads" || a == "--config" || a == "--preset" || a == "--rounds" {
            skip_next = true;
            continue;
        }
        if a.starts_with("--") {
            continue;
        }
        return Some(a);
    }
    None
}

fn cmd_figure(args: &[String]) -> Result<(), String> {
    let json = args.iter().any(|a| a == "--json");
    let runner = runner_from_args(args)?;
    let name = positional(args).ok_or("figure id required")?;
    let names: Vec<&str> = if name == "all" {
        experiments::ALL_FIGURES.to_vec()
    } else {
        vec![name.as_str()]
    };
    for n in names {
        let spec =
            experiments::spec_by_name(n).ok_or_else(|| format!("unknown figure '{n}'"))?;
        let fig = runner.run(&spec);
        if json {
            println!("{}", fig.to_json().pretty());
        } else {
            println!("{}", fig.to_table());
        }
    }
    Ok(())
}

fn cmd_ablation(args: &[String]) -> Result<(), String> {
    let json = args.iter().any(|a| a == "--json");
    let runner = runner_from_args(args)?;
    let name = positional(args).ok_or("ablation name required")?;
    let names: Vec<&str> = if name == "all" {
        experiments::ablations::ALL_ABLATIONS.to_vec()
    } else {
        vec![name.as_str()]
    };
    for n in names {
        let spec = experiments::ablations::spec_by_name(n)
            .ok_or_else(|| format!("unknown ablation '{n}'"))?;
        let fig = runner.run(&spec);
        if json {
            println!("{}", fig.to_json().pretty());
        } else {
            println!("{}", fig.to_table());
        }
    }
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<(), String> {
    let json = args.iter().any(|a| a == "--json");
    let runner = runner_from_args(args)?;
    let path = args
        .iter()
        .position(|a| a == "--config")
        .and_then(|i| args.get(i + 1))
        .ok_or("--config <file> required")?;
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let cfg = config::ExperimentConfig::from_str(&text)?;
    let fig = runner.run(&config_spec(&cfg));
    if json {
        println!("{}", fig.to_json().pretty());
    } else {
        println!("{}", fig.to_table());
    }
    Ok(())
}

/// `hemt sweep`: run a whole-grid scenario product (the built-in
/// tiny-tasks regime product, or a JSON `ProductSweepSpec` via
/// `--config`) through the sweep runner.
fn cmd_sweep(args: &[String]) -> Result<(), String> {
    let json = args.iter().any(|a| a == "--json");
    let runner = runner_from_args(args)?;
    let product = match args.iter().position(|a| a == "--config") {
        None => match args.iter().position(|a| a == "--preset") {
            None => hemt::sweep::ProductSweepSpec::tiny_tasks_regimes(),
            Some(i) => match args.get(i + 1).map(String::as_str) {
                Some("tiny_tasks") => hemt::sweep::ProductSweepSpec::tiny_tasks_regimes(),
                Some("dynamics") => hemt::sweep::ProductSweepSpec::dynamic_regimes(),
                Some(other) => {
                    return Err(format!(
                        "unknown preset '{other}' (expected tiny_tasks or dynamics)"
                    ))
                }
                None => return Err("--preset needs a value".into()),
            },
        },
        Some(i) => {
            let path = args.get(i + 1).ok_or("--config needs a value")?;
            let text =
                std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
            hemt::sweep::ProductSweepSpec::from_str(&text)?
        }
    };
    let spec = product.to_spec();
    eprintln!(
        "product sweep: {} cells x {} trials = {} units over {} thread(s)",
        product.num_cells(),
        product.trials,
        spec.num_units(),
        runner.threads()
    );
    let fig = runner.run(&spec);
    if json {
        println!("{}", fig.to_json().pretty());
    } else {
        println!("{}", fig.to_table());
    }
    Ok(())
}

/// `hemt dynamics`: the closed-loop comparison — Adaptive-HeMT (the
/// OA estimator loop re-partitioning between rounds) vs static-HeMT
/// (weights frozen at launch hints) vs HomT, across the capacity-program
/// families (Markov throttling, spot outage, diurnal interference,
/// credit cliff). All three arms of a family share one seed, hence one
/// capacity trace; output is bit-identical for any thread count.
///
/// With `--correlated`, the correlated-dynamics figures instead: the
/// `rack_steal` comparison (the steal arm set under rack-wide
/// shared-event degradation, where thieves degrade with victims) then
/// the `link_degrade` comparison (HeMT vs HomT on the 200 Mbps
/// read-heavy testbed with the datanode uplinks themselves
/// time-varying).
fn cmd_dynamics(args: &[String]) -> Result<(), String> {
    if args.iter().any(|a| a == "--correlated") {
        run_family_comparison(
            args,
            "rack-correlated steal comparison",
            4,
            hemt::dynamics::CORRELATED_FAMILIES,
            hemt::dynamics::CORRELATED_BASE_SEED,
            hemt::dynamics::correlated_steal_comparison_spec,
        )?;
        return run_family_comparison(
            args,
            "link-degradation comparison",
            3,
            hemt::dynamics::LINK_FAMILIES,
            hemt::dynamics::LINK_DEGRADE_BASE_SEED,
            hemt::dynamics::link_degrade_comparison_spec,
        );
    }
    run_family_comparison(
        args,
        "dynamics comparison",
        3,
        hemt::dynamics::COMPARISON_FAMILIES,
        hemt::dynamics::COMPARISON_BASE_SEED,
        hemt::dynamics::comparison_spec,
    )
}

/// `hemt steal`: the mid-stage work-stealing comparison — Steal-HeMT
/// (running tasks split on capacity events / idle nodes, the carved
/// remainder re-homed — [`hemt::coordinator::stealing`]) vs
/// Adaptive-HeMT vs static-HeMT vs HomT across the capacity-program
/// families. With `--streams`, the network-bound `net_steal` comparison
/// instead: stream-splitting stealing (in-flight reads truncated, the
/// unread range re-issued from a different replica) head-to-head with
/// CPU-only stealing. All arms of a family share one seed, hence one
/// capacity trace; output is bit-identical for any thread count.
fn cmd_steal(args: &[String]) -> Result<(), String> {
    if args.iter().any(|a| a == "--streams") {
        run_family_comparison(
            args,
            "stream-steal comparison",
            4,
            hemt::dynamics::NET_STEAL_FAMILIES,
            hemt::dynamics::NET_STEAL_BASE_SEED,
            hemt::dynamics::net_steal_comparison_spec,
        )
    } else {
        run_family_comparison(
            args,
            "steal comparison",
            4,
            hemt::dynamics::COMPARISON_FAMILIES,
            hemt::dynamics::COMPARISON_BASE_SEED,
            hemt::dynamics::steal_comparison_spec,
        )
    }
}

/// Shared skeleton of the per-family policy comparisons (`hemt
/// dynamics`, `hemt steal[ --streams]`): parse flags, run the spec,
/// print the figure and the per-family winners.
fn run_family_comparison(
    args: &[String],
    banner: &str,
    arms: usize,
    families: &[&str],
    base_seed: u64,
    spec_of: impl Fn(usize, u64) -> hemt::sweep::SweepSpec,
) -> Result<(), String> {
    let json = args.iter().any(|a| a == "--json");
    let runner = runner_from_args(args)?;
    let rounds = rounds_arg(args)?;
    let spec = spec_of(rounds, base_seed);
    eprintln!(
        "{banner}: {} families x {arms} policies x {rounds} rounds over {} thread(s)",
        families.len(),
        runner.threads()
    );
    let fig = runner.run(&spec);
    if json {
        println!("{}", fig.to_json().pretty());
        return Ok(());
    }
    println!("{}", fig.to_table());
    print_family_winners(&fig, families, rounds);
    Ok(())
}

/// Parse `--rounds N` (default: the dynamics comparison's round count).
fn rounds_arg(args: &[String]) -> Result<usize, String> {
    match args.iter().position(|a| a == "--rounds") {
        None => Ok(hemt::dynamics::DEFAULT_ROUNDS),
        Some(i) => {
            let n: usize = args
                .get(i + 1)
                .ok_or("--rounds needs a value")?
                .parse()
                .map_err(|e| format!("bad --rounds: {e}"))?;
            if n == 0 {
                return Err("--rounds must be >= 1".into());
            }
            Ok(n)
        }
    }
}

/// Per-family verdict: which policy's mean round time wins.
fn print_family_winners(fig: &hemt::metrics::Figure, families: &[&str], rounds: usize) {
    println!("per-family winners (mean map-stage time over {rounds} rounds):");
    for (fi, family) in families.iter().enumerate() {
        let mut best: Option<(&str, f64)> = None;
        for s in &fig.series {
            if let Some(p) = s.points.iter().find(|p| p.x == fi as f64) {
                match best {
                    Some((_, b)) if b <= p.stats.mean => {}
                    _ => best = Some((s.name.as_str(), p.stats.mean)),
                }
            }
        }
        if let Some((name, mean)) = best {
            println!("  {family:<13} -> {name} ({mean:.1} s)");
        }
    }
}

/// `hemt bench-diff`: the CI bench-trajectory gate. Compares medians of
/// `BENCH_*.json` files in `--new` against `--baseline`; exits non-zero
/// when any bench regressed past the threshold or went missing.
fn cmd_bench_diff(args: &[String]) -> Result<(), String> {
    use hemt::bench_harness as bh;
    let dir_arg = |flag: &str| -> Result<std::path::PathBuf, String> {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .map(std::path::PathBuf::from)
            .ok_or_else(|| format!("{flag} <dir> required"))
    };
    let baseline = dir_arg("--baseline")?;
    let new = dir_arg("--new")?;
    let threshold: f64 = match args.iter().position(|a| a == "--threshold") {
        None => 0.15,
        Some(i) => args
            .get(i + 1)
            .ok_or("--threshold needs a value")?
            .parse()
            .map_err(|e| format!("bad --threshold: {e}"))?,
    };
    if args.iter().any(|a| a == "--update") {
        let copied = bh::update_baselines(&baseline, &new)?;
        println!("updated {} baseline report(s) in {}:", copied.len(), baseline.display());
        for name in copied {
            println!("  {name}");
        }
        return Ok(());
    }
    let report = bh::compare_bench_dirs(&baseline, &new, threshold)?;
    if report.is_empty() {
        println!(
            "bench-diff: no BENCH_*.json in {} or {} — nothing to gate",
            baseline.display(),
            new.display()
        );
        return Ok(());
    }
    print!("{}", bh::trajectory_table(&report, threshold));
    if bh::trajectory_passes(&report) {
        println!("bench trajectory: OK");
        Ok(())
    } else {
        Err(format!(
            "bench trajectory gate failed (>{:.0}% median regression or missing bench); \
             refresh intentionally with `hemt bench-diff --baseline {} --new {} --update`",
            threshold * 100.0,
            baseline.display(),
            new.display()
        ))
    }
}

/// Express a config file as a sweep spec: `trials` runs of the configured
/// workload under the configured policy, reporting completion-time stats.
fn config_spec(cfg: &config::ExperimentConfig) -> hemt::sweep::SweepSpec {
    let mut spec =
        hemt::sweep::SweepSpec::new(&cfg.name, "trial set", "completion time (s)");
    let series = spec.series(cfg.workload.kind.name());
    spec.scenario(
        series,
        0.0,
        &cfg.name,
        hemt::sweep::Scenario {
            cluster: cfg.cluster.clone(),
            workload: cfg.workload.clone(),
            policy: cfg.policy.clone(),
            dynamics: hemt::dynamics::DynamicsConfig::steady(),
            metric: hemt::sweep::Metric::JobTime,
            trials: cfg.trials,
            base_seed: cfg.base_seed,
        },
    );
    spec
}

fn cmd_analysis() -> Result<(), String> {
    println!("Claim 2 (Sec. 3): same-datanode collision probabilities");
    println!("{:>4} {:>4} {:>10} {:>10}", "n", "r", "p1", "p2");
    for r in [2usize, 3] {
        for n in [r, 4, 8, 16, 30] {
            if n >= r {
                println!(
                    "{:>4} {:>4} {:>10.4} {:>10.4}",
                    n,
                    r,
                    analysis::p1(r),
                    analysis::p2(n, r)
                );
            }
        }
    }
    println!();
    println!("Claim 1 (Sec. 3): pull-based idle-time bound demo (speeds 1.0/0.4)");
    for m in [2usize, 8, 32] {
        let f = analysis::pull_schedule_finish_times(&[1.0, 0.4], 100.0 / m as f64, m);
        println!(
            "  m={m:>3}: idle {:>7.2} s <= bound {:>7.2} s",
            analysis::idle_time(&f),
            analysis::claim1_bound(&[100.0 / m as f64 / 1.0, 100.0 / m as f64 / 0.4])
        );
    }
    Ok(())
}

fn cmd_plan_credits(args: &[String]) -> Result<(), String> {
    let work_pos = args
        .iter()
        .position(|a| a == "--work")
        .ok_or("--work <cpu-minutes> required")?;
    let work: f64 = args
        .get(work_pos + 1)
        .ok_or("--work needs a value")?
        .parse()
        .map_err(|e| format!("bad --work: {e}"))?;
    let credits: Vec<f64> = args
        .iter()
        .enumerate()
        .filter(|&(i, a)| !a.starts_with("--") && i != work_pos + 1)
        .map(|(_, a)| a.parse().map_err(|e| format!("bad credit value '{a}': {e}")))
        .collect::<Result<_, _>>()?;
    if credits.is_empty() {
        return Err("need at least one node's credit balance".into());
    }
    let curves: Vec<CreditCurve> = credits.iter().map(|&c| CreditCurve::t2_small(c)).collect();
    let p = plan(&curves, work).ok_or("workload unreachable with these curves")?;
    println!("t' = {:.4} minutes (all nodes finish simultaneously)", p.t_prime);
    for (i, (c, share)) in credits.iter().zip(p.shares.iter()).enumerate() {
        println!(
            "  node {i}: credits {c:>6.2} -> share {share:>8.4} CPU-min ({:.1}%)",
            100.0 * share / work
        );
    }
    Ok(())
}

fn cmd_real(args: &[String]) -> Result<(), String> {
    let wl = args.first().ok_or("workload required: wordcount|kmeans|pagerank")?;
    hemt::exec::demo::run_demo(wl).map_err(|e| format!("{e:#}"))
}

fn cmd_artifacts() -> Result<(), String> {
    let rt = hemt::runtime::Runtime::load_default().map_err(|e| format!("{e:#}"))?;
    println!("platform: {}", rt.platform());
    println!("artifacts dir: {}", rt.artifacts_dir().display());
    for name in rt.artifact_names() {
        println!("  {name}");
    }
    Ok(())
}

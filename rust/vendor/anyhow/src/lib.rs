//! Offline shim for the `anyhow` crate: the subset of its API this
//! repository uses, implemented over a plain message chain so the build
//! needs no network or registry access.
//!
//! Covered surface: [`Error`], [`Result`], the [`anyhow!`], [`bail!`] and
//! [`ensure!`] macros, and the [`Context`] extension trait with
//! `context`/`with_context` on `Result`. `{:#}` formatting prints the
//! full cause chain like the real crate.

use std::fmt;

/// A dynamically-typed error: a message plus an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The outermost message and each cause below it, in order.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain().join(": "))
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

// Like the real crate: any std error converts into `Error` (so `?` works),
// which is only coherent because `Error` itself is not a `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = Vec::new();
        msgs.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(match err {
                None => Error::msg(m),
                Some(inner) => inner.context(m),
            });
        }
        err.expect("at least one message")
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42);
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "inner 42");
    }

    #[test]
    fn context_chains_and_alternate_prints_chain() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
    }

    #[test]
    fn with_context_on_io_error() {
        let r: std::io::Result<()> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.with_context(|| "reading manifest").unwrap_err();
        assert!(format!("{e:#}").starts_with("reading manifest: "));
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(-1).is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
    }
}

//! Compile-time stub of the `xla` (PJRT) bindings.
//!
//! The real-execution mode (`hemt real`, `examples/{kmeans,pagerank}_cluster`)
//! needs the XLA PJRT C++ runtime, which is not part of the offline build
//! environment. This stub provides the exact API surface the repository
//! uses so everything compiles and the simulation path is fully usable;
//! every runtime entry point returns an "unavailable" error instead of
//! executing. Swapping in the real `xla` bindings (same module paths)
//! re-enables real execution without source changes — see rust/README.md.

use std::fmt;

/// Error raised by every stubbed runtime entry point.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT runtime unavailable (built with the offline stub backend — \
         link the real xla bindings to enable real execution)"
    ))
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + 'static {}

impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u64 {}
impl NativeType for f32 {}
impl NativeType for f64 {}

/// A host-side tensor value (stub: shape/data are not retained).
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal { _private: () }
    }

    /// Reinterpret under a new shape.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// A parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from an HLO module (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A device-resident buffer (stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A PJRT client (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert!(lit.to_vec::<i32>().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}

//! Ablation bench: OA-HeMT forgetting-factor tradeoff.
//! Run via `cargo bench --bench ablation_alpha`.
use hemt::bench_harness::run_figure_bench;
use hemt::experiments;

fn main() {
    run_figure_bench("ablation_alpha", 1, experiments::ablations::alpha);
}

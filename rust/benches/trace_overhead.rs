//! Recorder overhead bench: the headline static-container figure (fig9)
//! run untraced on the serial runner vs the same run through
//! [`hemt::api::execute_traced`] with the span recorder installed. The
//! recorder's contract is bit-identical *output*; this bench tracks its
//! wall-clock cost — every hook is a thread-local check plus (when
//! installed) a vector push, so traced should stay within a few percent
//! of untraced.
//!
//! Writes `BENCH_trace_overhead_untraced.json` and
//! `BENCH_trace_overhead.json` for the CI trajectory gate.

use hemt::api::{self, RunRequest};
use hemt::bench_harness::time_and_report;
use hemt::obs;

fn main() {
    let req = RunRequest::Figure { name: "fig9".into() };
    println!("== trace_overhead: fig9 untraced vs span-recorded (serial) ==");
    let untraced = time_and_report("trace_overhead_untraced", 1, 3, || {
        std::hint::black_box(
            api::execute_with(&req, &hemt::sweep::SweepRunner::serial(), |_| {}).unwrap(),
        );
    });
    let mut events = 0usize;
    let traced = time_and_report("trace_overhead", 1, 3, || {
        let (result, rec) = api::execute_traced(&req, |_| {}).unwrap();
        std::hint::black_box(result);
        events = rec.events.len();
        // Export cost rides along: the trace document is part of what
        // `hemt trace` pays per invocation.
        std::hint::black_box(obs::chrome_trace(&rec));
    });
    println!(
        "trace_overhead_untraced: {} s\ntrace_overhead (traced): {} s  ({:+.1}% overhead, {events} events)",
        untraced.pm(3),
        traced.pm(3),
        (traced.mean / untraced.mean - 1.0) * 100.0,
    );
}

//! Ablation bench: HDFS rack awareness.
//! Run via `cargo bench --bench ablation_rack`.
use hemt::bench_harness::run_figure_bench;
use hemt::experiments;

fn main() {
    run_figure_bench("ablation_rack", 1, experiments::ablations::rack_awareness);
}

//! Bench: regenerate the paper's "Fig 7 OA-HeMT under interference" and time the experiment driver.
//! Run via `cargo bench --bench fig07_adaptive_interference`.
use hemt::bench_harness::run_figure_bench;
use hemt::experiments;

fn main() {
    run_figure_bench("fig07_adaptive_interference", 1, experiments::fig7);
}

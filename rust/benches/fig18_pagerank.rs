//! Bench: regenerate the paper's "Fig 18 PageRank" and time the experiment driver.
//! Run via `cargo bench --bench fig18_pagerank`.
use hemt::bench_harness::run_figure_bench;
use hemt::experiments;

fn main() {
    run_figure_bench("fig18_pagerank", 1, experiments::fig18);
}

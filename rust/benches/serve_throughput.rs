//! `hemt serve` throughput bench: spin up the real server (loopback
//! TCP, SSE streaming, memo + session pool) and measure two paths the
//! service lives or dies by:
//!
//! * `serve_throughput` — a batch of *distinct* tiny product-sweep
//!   specs submitted concurrently: full compute per spec, but every
//!   trial of every spec reuses the pooled cluster session
//!   ([`hemt::sweep::cached_session`] keys on the cluster alone).
//! * `serve_memo_hit` — resubmitting one already-computed spec over and
//!   over: the pure replay path (parse → hash → stream stored frames),
//!   which is what a dashboard hammering the service actually exercises.
//!
//! Writes `BENCH_serve_throughput.json` and `BENCH_serve_memo_hit.json`
//! for the CI trajectory gate.

use hemt::api::RunRequest;
use hemt::bench_harness::time_and_report;
use hemt::config::{ClusterConfig, PolicyConfig, WorkloadConfig};
use hemt::serve::{client, spawn, ServeConfig};
use hemt::sweep::{Metric, Named, ProductSweepSpec};

fn tiny_body(base_seed: u64) -> String {
    let mut wl = WorkloadConfig::wordcount_2gb();
    wl.data_mb = 256;
    wl.block_mb = 128;
    let spec = ProductSweepSpec {
        title: format!("bench product {base_seed}"),
        dynamics: ProductSweepSpec::steady_axis(),
        clusters: vec![Named::new("static", ClusterConfig::containers_1_and_04())],
        workloads: vec![Named::new("wc", wl)],
        policies: vec![
            Named::new("homt", PolicyConfig::Homt(2)),
            Named::new("hemt", PolicyConfig::HemtFromHints),
        ],
        granularities: vec![2, 8],
        metric: Metric::MapStageTime,
        trials: 2,
        base_seed,
    };
    RunRequest::ProductSweep { spec }.to_json().compact()
}

fn submit_batch(addr: &str, seeds: &[u64]) {
    std::thread::scope(|scope| {
        let handles: Vec<_> = seeds
            .iter()
            .map(|&seed| {
                let body = tiny_body(seed);
                scope.spawn(move || {
                    let mut done = false;
                    let (status, err) = client::post_sse(addr, "/run", &body, |ev, _| {
                        done = done || ev == "done";
                    })
                    .expect("submit");
                    assert_eq!(status, 200, "{err}");
                    assert!(done, "stream must complete");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

fn main() {
    let workers = 2;
    let handle = spawn(ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers,
        threads: 2,
        max_queue: 64,
        paused: false,
        ..ServeConfig::default()
    })
    .expect("bind loopback");
    let addr = handle.addr().to_string();
    println!("== serve_throughput: {workers} workers x 2 sweep threads on {addr} ==");

    // Distinct specs per iteration (seed varies per round and per slot)
    // so each batch is real compute, never a memo replay.
    let mut round: u64 = 0;
    let throughput = time_and_report("serve_throughput", 1, 3, || {
        let seeds: Vec<u64> = (0..6).map(|i| 1_000_000 + round * 100 + i).collect();
        submit_batch(&addr, &seeds);
        round += 1;
    });
    println!("serve_throughput (6 specs/batch): {} s", throughput.pm(3));

    // Replay path: one spec, computed once above the timer, then
    // resubmitted — memo hits only.
    let replay_body = tiny_body(9_999_999);
    submit_batch(&addr, &[9_999_999]);
    let memo = time_and_report("serve_memo_hit", 1, 5, || {
        for _ in 0..20 {
            let raw = client::raw_request(&addr, "POST", "/run", Some(&replay_body))
                .expect("replay");
            assert!(!raw.is_empty());
        }
    });
    println!("serve_memo_hit (20 replays/iter): {} s", memo.pm(3));

    let metrics = client::request(&addr, "GET", "/metrics", None).expect("metrics");
    println!();
    println!("{}", metrics.body_str());
    handle.shutdown();
    handle.join();
}

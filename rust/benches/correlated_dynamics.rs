//! Correlated-dynamics bench: the two `hemt dynamics --correlated`
//! figures timed through the sweep runner, serial baseline vs the
//! machine's full pool.
//!
//! `rack_steal` drives the SharedEvent fan-out path (one realization
//! replayed on every node, the steal drivers probing a world where
//! thieves degrade with victims); `link_degrade` drives the
//! link-capacity playback path (compiled LinkPrograms applied mid-stage
//! through the dirty-link incremental solve on the 200 Mbps read-heavy
//! testbed). Writes `BENCH_correlated_dynamics.json` (pooled) and
//! `BENCH_correlated_dynamics_serial.json` for the CI trajectory gate.

use hemt::bench_harness::time_and_report;
use hemt::dynamics::{
    correlated_steal_comparison_spec, link_degrade_comparison_spec, CORRELATED_BASE_SEED,
    CORRELATED_FAMILIES, LINK_DEGRADE_BASE_SEED, LINK_FAMILIES,
};
use hemt::sweep::{session_cache_stats, SweepRunner};

const ROUNDS: usize = 6;

fn run_both(threads: usize) -> (hemt::metrics::Figure, hemt::metrics::Figure) {
    let rack =
        SweepRunner::new(threads).run(&correlated_steal_comparison_spec(ROUNDS, CORRELATED_BASE_SEED));
    let link =
        SweepRunner::new(threads).run(&link_degrade_comparison_spec(ROUNDS, LINK_DEGRADE_BASE_SEED));
    (rack, link)
}

fn main() {
    println!(
        "== correlated_dynamics: {} rack + {} link families x {ROUNDS} rounds ==",
        CORRELATED_FAMILIES.len(),
        LINK_FAMILIES.len()
    );
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let serial = time_and_report("correlated_dynamics_serial", 0, 3, || {
        std::hint::black_box(run_both(1));
    });
    let mut last = None;
    let pooled = time_and_report("correlated_dynamics", 0, 3, || {
        last = Some(run_both(threads));
    });
    let (hits, misses) = session_cache_stats();
    println!(
        "correlated_dynamics_serial:    {} s\ncorrelated_dynamics_pool({threads}): {} s  ({:.2}x)",
        serial.pm(3),
        pooled.pm(3),
        serial.mean / pooled.mean
    );
    println!("session cache: {hits} hits / {misses} misses");
    println!();
    let (rack, link) = last.expect("pooled run happened");
    println!("{}", rack.to_table());
    println!("{}", link.to_table());
}

//! Bench: regenerate the paper's "Fig 15 burstable 250 Mbps" and time the experiment driver.
//! Run via `cargo bench --bench fig15_burstable_250`.
use hemt::bench_harness::run_figure_bench;
use hemt::experiments;

fn main() {
    run_figure_bench("fig15_burstable_250", 1, experiments::fig15);
}

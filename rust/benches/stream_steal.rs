//! Stream-stealing comparison bench: the `hemt steal --streams`
//! four-arm figure (Stream-Steal-HeMT vs CPU-only Steal-HeMT vs
//! static-HeMT vs HomT on the network-bound testbed) timed through the
//! sweep runner, serial baseline vs the machine's full pool.
//!
//! Writes `BENCH_stream_steal.json` (pooled) and
//! `BENCH_stream_steal_serial.json` for the CI trajectory gate. The
//! stream arm exercises the whole new path — per-flow delivered-byte
//! tracking, `Engine::split_input_stream`, deterministic replica
//! re-selection and the stage-loop stream-victim scans — so this bench
//! is the end-to-end wall-clock trajectory of stream splitting.

use hemt::bench_harness::time_and_report;
use hemt::dynamics::{net_steal_comparison_spec, NET_STEAL_BASE_SEED, NET_STEAL_FAMILIES};
use hemt::sweep::{session_cache_stats, SweepRunner};

const ROUNDS: usize = 8;

fn main() {
    println!(
        "== stream_steal: {} families x 4 policies x {ROUNDS} rounds ==",
        NET_STEAL_FAMILIES.len()
    );
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let serial = time_and_report("stream_steal_serial", 0, 3, || {
        std::hint::black_box(
            SweepRunner::new(1).run(&net_steal_comparison_spec(ROUNDS, NET_STEAL_BASE_SEED)),
        );
    });
    let mut last = None;
    let pooled = time_and_report("stream_steal", 0, 3, || {
        last = Some(
            SweepRunner::new(threads)
                .run(&net_steal_comparison_spec(ROUNDS, NET_STEAL_BASE_SEED)),
        );
    });
    let (hits, misses) = session_cache_stats();
    println!(
        "stream_steal_serial:    {} s\nstream_steal_pool({threads}): {} s  ({:.2}x)",
        serial.pm(3),
        pooled.pm(3),
        serial.mean / pooled.mean
    );
    println!("session cache: {hits} hits / {misses} misses");
    println!();
    println!("{}", last.expect("pooled run happened").to_table());
}

//! Bench: regenerate the paper's "Fig 17 K-Means" and time the experiment driver.
//! Run via `cargo bench --bench fig17_kmeans`.
use hemt::bench_harness::run_figure_bench;
use hemt::experiments;

fn main() {
    run_figure_bench("fig17_kmeans", 1, experiments::fig17);
}

//! Bench: regenerate the paper's "Headline summary" and time the experiment driver.
//! Run via `cargo bench --bench headline_summary`.
use hemt::bench_harness::run_figure_bench;
use hemt::experiments;

fn main() {
    run_figure_bench("headline_summary", 1, experiments::headline);
}

//! Bench: regenerate the paper's "Fig 8 OA-HeMT convergence" and time the experiment driver.
//! Run via `cargo bench --bench fig08_adaptive_provisioned`.
use hemt::bench_harness::run_figure_bench;
use hemt::experiments;

fn main() {
    run_figure_bench("fig08_adaptive_provisioned", 1, experiments::fig8);
}

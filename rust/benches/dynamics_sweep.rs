//! Dynamics-comparison bench: the `hemt dynamics` closed-loop figure
//! (Adaptive-HeMT vs static-HeMT vs HomT across the capacity-program
//! families) timed through the sweep runner, serial baseline vs the
//! machine's full pool.
//!
//! Writes `BENCH_dynamics_sweep.json` (pooled) and
//! `BENCH_dynamics_sweep_serial.json` for the CI trajectory gate. The
//! units lean on the new per-node dirty-mark CPU re-level (every
//! capacity event used to trigger a whole-engine water-fill rebuild) and
//! on the session cache (the three arms of a family share one pristine
//! session), so this bench is the end-to-end trajectory of both.

use hemt::bench_harness::time_and_report;
use hemt::dynamics::{comparison_spec, COMPARISON_BASE_SEED, COMPARISON_FAMILIES};
use hemt::sweep::{session_cache_stats, SweepRunner};

const ROUNDS: usize = 8;

fn main() {
    println!(
        "== dynamics_sweep: {} families x 3 policies x {ROUNDS} rounds ==",
        COMPARISON_FAMILIES.len()
    );
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let serial = time_and_report("dynamics_sweep_serial", 0, 3, || {
        std::hint::black_box(
            SweepRunner::new(1).run(&comparison_spec(ROUNDS, COMPARISON_BASE_SEED)),
        );
    });
    let mut last = None;
    let pooled = time_and_report("dynamics_sweep", 0, 3, || {
        last = Some(
            SweepRunner::new(threads).run(&comparison_spec(ROUNDS, COMPARISON_BASE_SEED)),
        );
    });
    let (hits, misses) = session_cache_stats();
    println!(
        "dynamics_sweep_serial:    {} s\ndynamics_sweep_pool({threads}): {} s  ({:.2}x)",
        serial.pm(3),
        pooled.pm(3),
        serial.mean / pooled.mean
    );
    println!("session cache: {hits} hits / {misses} misses");
    println!();
    println!("{}", last.expect("pooled run happened").to_table());
}

//! Bench: regenerate the paper's "Fig 5 network-bottleneck sweep" and time the experiment driver.
//! Run via `cargo bench --bench fig05_network_bottleneck`.
use hemt::bench_harness::run_figure_bench;
use hemt::experiments;

fn main() {
    run_figure_bench("fig05_network_bottleneck", 1, experiments::fig5);
}

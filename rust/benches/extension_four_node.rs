//! Extension bench: 4-node mixed-cluster generality check.
//! Run via `cargo bench --bench extension_four_node`.
use hemt::bench_harness::run_figure_bench;
use hemt::experiments;

fn main() {
    run_figure_bench("extension_four_node", 1, experiments::extension::four_node);
}

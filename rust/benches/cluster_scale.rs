//! Engine event throughput on the datacenter-scale ladder: the PR 9
//! refactor's headline number. Drains an identical volume of chained
//! CPU-job events (plus mid-run capacity bursts) through clusters of
//! 100, 1k and 10k nodes — before the sharded heaps / arena / volatile
//! partition, cost per event grew with cluster size; now the three
//! rungs should sit within a small factor of each other.
//!
//! Writes `BENCH_cluster_scale_n{100,1k,10k}.json` into
//! `$HEMT_BENCH_DIR` (default `bench_results/`) for the CI
//! bench-trajectory gate. Run via `cargo bench --bench cluster_scale`.

use hemt::bench_harness::time_and_report as timed;
use hemt::netsim::NetSim;
use hemt::nodes::Node;
use hemt::sim::{Engine, Event};

/// Node speeds (cores), cycled across the cluster.
const SPEEDS: [f64; 4] = [1.0, 0.8, 0.6, 0.4];
/// Total chained tasks per drain — constant across rungs, so the three
/// timings isolate the cost of cluster size, not workload size.
const TASKS: usize = 100_000;
/// Capacity-burst timers per drain: each throttles or restores every
/// 16th node in one batch, the dynamics-playback access pattern.
const BURSTS: usize = 4;

const BURST_TAG_BASE: u64 = 1 << 40;

/// Drain `TASKS` chained unit jobs through an `n`-node engine; returns
/// the number of events delivered.
fn drain(n: usize) -> usize {
    let jobs_per_node = TASKS / n;
    let nodes: Vec<Node> = (0..n)
        .map(|i| Node::fixed(&format!("n{i}"), SPEEDS[i % 4]))
        .collect();
    let mut e = Engine::new(nodes, NetSim::new());
    let mut left = vec![jobs_per_node - 1; n];
    for node in 0..n {
        e.add_cpu_job(node, SPEEDS[node % 4], 1.0, node as u64);
    }
    // Slowest rung finishes at jobs_per_node / 0.4; spread the bursts
    // over the first half so throttled nodes still drain in-window.
    let horizon = jobs_per_node as f64 * 2.5;
    for k in 0..BURSTS {
        let at = horizon * 0.5 * (k + 1) as f64 / BURSTS as f64;
        e.set_timer(at, BURST_TAG_BASE + k as u64);
    }
    let mut events = 0usize;
    while let Some(ev) = e.step() {
        events += 1;
        match ev {
            Event::Timer { tag } => {
                let mult = if (tag - BURST_TAG_BASE) % 2 == 0 { 0.5 } else { 1.0 };
                for node in (0..n).step_by(16) {
                    e.set_node_capacity(node, mult);
                }
            }
            Event::JobDone { tag, .. } => {
                let node = tag as usize;
                if left[node] > 0 {
                    left[node] -= 1;
                    e.add_cpu_job(node, SPEEDS[node % 4], 1.0, tag);
                }
            }
            Event::FlowDone { .. } => unreachable!("no flows in this bench"),
        }
    }
    events
}

fn bench_rung(name: &str, n: usize) {
    let expected = TASKS / n * n + BURSTS;
    let s = timed(name, 1, 5, || {
        assert_eq!(drain(n), expected);
    });
    println!(
        "{name}: {:>12.0} events/s  ({} s per {expected}-event drain)",
        expected as f64 / s.mean,
        s.pm(4)
    );
}

fn main() {
    println!("== cluster_scale (engine throughput vs cluster size) ==");
    bench_rung("cluster_scale_n100", 100);
    bench_rung("cluster_scale_n1k", 1_000);
    bench_rung("cluster_scale_n10k", 10_000);
}

//! Ablation bench: credit-planner staleness.
//! Run via `cargo bench --bench ablation_stale_credits`.
use hemt::bench_harness::run_figure_bench;
use hemt::experiments;

fn main() {
    run_figure_bench("ablation_stale_credits", 1, experiments::ablations::stale_credits);
}

//! Bench: regenerate the paper's "Figs 10-12 credit planner" and time the experiment driver.
//! Run via `cargo bench --bench fig10_12_credit_planner`.
use hemt::bench_harness::run_figure_bench;
use hemt::experiments;

fn main() {
    run_figure_bench("fig10_12_credit_planner", 1, experiments::fig10_12);
}

//! Ablation bench: speculative execution vs HeMT.
//! Run via `cargo bench --bench ablation_speculation`.
use hemt::bench_harness::run_figure_bench;
use hemt::experiments;

fn main() {
    run_figure_bench("ablation_speculation", 1, experiments::ablations::speculation);
}

//! Bench: regenerate the paper's "Fig 14 burstable 480 Mbps" and time the experiment driver.
//! Run via `cargo bench --bench fig14_burstable_480`.
use hemt::bench_harness::run_figure_bench;
use hemt::experiments;

fn main() {
    run_figure_bench("fig14_burstable_480", 1, experiments::fig14);
}

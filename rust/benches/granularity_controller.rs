//! Granularity-controller bench: the headline `controller_grid` figure
//! (the online auto-granularity controller vs every fixed policy arm
//! across all compute-bound dynamics families) timed through the sweep
//! runner, serial baseline vs the machine's full pool.
//!
//! Writes `BENCH_granularity_controller.json` (pooled) and
//! `BENCH_granularity_controller_serial.json` for the CI trajectory
//! gate. Beyond the fixed arms' closed loops, the units exercise the
//! controller's per-round decision path — posterior assembly from the
//! estimator's dispersion tracking, overhead EWMAs, and the arm switch
//! between plain/stealing/microtask execution — so this bench is the
//! end-to-end trajectory of the whole decision layer.

use hemt::bench_harness::time_and_report;
use hemt::dynamics::{controller_grid_spec, CONTROLLER_GRID_BASE_SEED, GRID_FAMILIES};
use hemt::sweep::{session_cache_stats, SweepRunner};

const ROUNDS: usize = 8;

fn main() {
    println!(
        "== granularity_controller: {} families x 5 policies x {ROUNDS} rounds ==",
        GRID_FAMILIES.len()
    );
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let serial = time_and_report("granularity_controller_serial", 0, 3, || {
        std::hint::black_box(
            SweepRunner::new(1).run(&controller_grid_spec(ROUNDS, CONTROLLER_GRID_BASE_SEED)),
        );
    });
    let mut last = None;
    let pooled = time_and_report("granularity_controller", 0, 3, || {
        last = Some(
            SweepRunner::new(threads).run(&controller_grid_spec(ROUNDS, CONTROLLER_GRID_BASE_SEED)),
        );
    });
    let (hits, misses) = session_cache_stats();
    println!(
        "granularity_controller_serial:    {} s\ngranularity_controller_pool({threads}): {} s  ({:.2}x)",
        serial.pm(3),
        pooled.pm(3),
        serial.mean / pooled.mean
    );
    println!("session cache: {hits} hits / {misses} misses");
    println!();
    println!("{}", last.expect("pooled run happened").to_table());
}

//! Bench: regenerate the paper's "Fig 9 static containers U-curve" and time the experiment driver.
//! Run via `cargo bench --bench fig09_static_containers`.
use hemt::bench_harness::run_figure_bench;
use hemt::experiments;

fn main() {
    run_figure_bench("fig09_static_containers", 1, experiments::fig9);
}

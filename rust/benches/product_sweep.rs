//! Whole-grid product-sweep bench: the built-in tiny-tasks regime
//! product (clusters × workloads × policies × granularities — what
//! `hemt sweep` runs) timed through the sweep runner, serial baseline vs
//! the machine's full pool.
//!
//! Writes `BENCH_product_sweep.json` (pooled) and
//! `BENCH_product_sweep_serial.json` for the CI trajectory gate; the
//! pooled/serial ratio is the sweep subsystem's parallel speedup on a
//! whole-grid unit mix (the shuffle-heavy PageRank cells are the ones
//! that lean on the incremental network engine).

use hemt::bench_harness::time_and_report;
use hemt::sweep::{ProductSweepSpec, SweepRunner};

fn main() {
    let product = ProductSweepSpec::tiny_tasks_regimes();
    let spec = product.to_spec();
    println!(
        "== product_sweep: {} cells x {} trials = {} units ==",
        product.num_cells(),
        product.trials,
        spec.num_units()
    );

    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let serial = time_and_report("product_sweep_serial", 0, 3, || {
        std::hint::black_box(SweepRunner::new(1).run(&product.to_spec()));
    });
    let mut last = None;
    let pooled = time_and_report("product_sweep", 0, 3, || {
        last = Some(SweepRunner::new(threads).run(&product.to_spec()));
    });
    println!(
        "product_sweep_serial:    {} s\nproduct_sweep_pool({threads}): {} s  ({:.2}x)",
        serial.pm(3),
        pooled.pm(3),
        serial.mean / pooled.mean
    );
    println!();
    println!("{}", last.expect("pooled run happened").to_table());
}

//! Bench: regenerate the paper's "Fig 4 closed forms" and time the experiment driver.
//! Run via `cargo bench --bench fig04_replica_prob`.
use hemt::bench_harness::run_figure_bench;
use hemt::experiments;

fn main() {
    run_figure_bench("fig04_replica_prob", 1, experiments::fig4);
}

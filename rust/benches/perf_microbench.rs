//! Performance microbenchmarks of the L3 hot paths — the §Perf
//! measurement harness (see EXPERIMENTS.md §Perf).
//!
//! Covers: DES event throughput, max-min rate recomputation under load
//! (incremental vs from-scratch, in both the multi-rack regime where the
//! incremental engine's component scoping wins and the fully-coupled
//! shuffle regime where it must at least match the full solve),
//! partitioner cost, skewed-hash bucket assignment, and the end-to-end
//! figure-sweep drivers that dominate `cargo bench` wall-clock.
//!
//! Every sub-bench writes a machine-readable `BENCH_<name>.json` into
//! `$HEMT_BENCH_DIR` (default `bench_results/`) — these files feed the
//! CI bench-trajectory gate (`hemt bench-diff`).
//! Run via `cargo bench --bench perf_microbench`.

use hemt::bench_harness::time_and_report as timed;
use hemt::netsim::NetSim;
use hemt::nodes::Node;
use hemt::partition::{Partitioning, SkewedHashPartitioner};
use hemt::sim::Engine;
use hemt::util::Rng;

fn bench_engine_event_throughput() {
    // 512 cpu jobs + 512 timers on 8 nodes: measure drained events/sec.
    let mk = || {
        let mut net = NetSim::new();
        let _ = net.add_link("l", 1e9);
        let nodes: Vec<Node> = (0..8).map(|i| Node::fixed(&format!("n{i}"), 1.0)).collect();
        let mut e = Engine::new(nodes, net);
        for i in 0..512u64 {
            e.add_cpu_job((i % 8) as usize, 1.0, 1.0 + (i % 7) as f64, i);
            e.set_timer(i as f64 * 0.01, 10_000 + i);
        }
        e
    };
    let events = 1024.0;
    let s = timed("engine_event_throughput", 1, 5, || {
        let mut e = mk();
        let n = e.run_to_end().len();
        assert_eq!(n, 1024);
    });
    println!(
        "engine_event_throughput: {:>10.0} events/s  ({} s per drain)",
        events / s.mean,
        s.pm(4)
    );
}

/// Fully-coupled topology: 256 flows over 16 shared links — every churn
/// touches one giant component, so the incremental path falls back to
/// the full solve and must not be slower than calling it directly.
fn bench_netsim_coupled() {
    let mut net = NetSim::new();
    let links: Vec<usize> = (0..16).map(|i| net.add_link(&format!("l{i}"), 1e8)).collect();
    let mut rng = Rng::new(1);
    for t in 0..256u64 {
        let a = links[rng.below(16)];
        let b = links[rng.below(16)];
        let route = if a == b { vec![a] } else { vec![a, b] };
        net.add_flow(route, 1e9, t);
    }
    let s_full = timed("netsim_full_256f_16l", 3, 20, || {
        let id = net.add_flow(vec![links[0]], 1e9, 999);
        net.recompute_rates_full();
        net.remove_flow(id);
        net.recompute_rates_full();
    });
    let s_incr = timed("netsim_incremental_256f_16l", 3, 20, || {
        let id = net.add_flow(vec![links[0]], 1e9, 999);
        net.recompute_rates();
        net.remove_flow(id);
        net.recompute_rates();
    });
    println!("netsim_full_256f_16l:        {} s", s_full.pm(6));
    println!(
        "netsim_incremental_256f_16l: {} s  ({:.2}x, coupled: parity expected)",
        s_incr.pm(6),
        s_full.mean / s_incr.mean
    );
}

/// Multi-rack topology: 32 racks × (uplink, downlink) with 8 steady
/// cross-link flows each, churning one rack at a time — the regime the
/// incremental engine is built for (shuffle-heavy sweeps where one
/// transfer finishes while unrelated racks' flows keep streaming).
fn bench_netsim_multirack() {
    const RACKS: usize = 32;
    const FLOWS_PER_RACK: usize = 8;
    let mut net = NetSim::new();
    let mut rack_links = Vec::new();
    for r in 0..RACKS {
        let up = net.add_link(&format!("up{r}"), 1e8);
        let down = net.add_link(&format!("down{r}"), 1e8);
        rack_links.push((up, down));
        for t in 0..FLOWS_PER_RACK {
            net.add_flow(vec![up, down], 1e9, (r * FLOWS_PER_RACK + t) as u64);
        }
    }
    net.recompute_rates();
    // One churn pass = complete-and-replace one flow in every rack, with
    // a rate refresh after each mutation (the engine's access pattern).
    let s_incr = timed("netsim_incremental_multirack", 2, 10, || {
        for (r, &(up, down)) in rack_links.iter().enumerate() {
            let id = net.add_flow(vec![up, down], 1e9, 10_000 + r as u64);
            net.recompute_rates();
            net.remove_flow(id);
            net.recompute_rates();
        }
    });
    let s_full = timed("netsim_full_multirack", 2, 10, || {
        for (r, &(up, down)) in rack_links.iter().enumerate() {
            let id = net.add_flow(vec![up, down], 1e9, 20_000 + r as u64);
            net.recompute_rates_full();
            net.remove_flow(id);
            net.recompute_rates_full();
        }
    });
    println!("netsim_full_multirack:        {} s", s_full.pm(6));
    println!(
        "netsim_incremental_multirack: {} s  ({:.2}x speedup from component scoping)",
        s_incr.pm(6),
        s_full.mean / s_incr.mean
    );
    let st = net.stats;
    println!(
        "  solver paths: {} incremental / {} full ({} flows re-levelled incrementally)",
        st.incremental_solves, st.full_solves, st.flows_relevelled
    );
}

fn bench_partitioners() {
    let weights: Vec<f64> = (1..=64).map(|i| i as f64).collect();
    let s = timed("hemt_partition_64w", 10, 50, || {
        let p = Partitioning::hemt(2 << 30, &weights);
        assert_eq!(p.num_tasks(), 64);
    });
    println!("hemt_partition_64w: {} s", s.pm(8));

    let part = SkewedHashPartitioner::new(&weights, 1 << 20);
    let mut rng = Rng::new(2);
    let hashes: Vec<u64> = (0..100_000).map(|_| rng.next_u64()).collect();
    let s = timed("skewed_hash_bucket", 2, 10, || {
        let mut acc = 0usize;
        for &h in &hashes {
            acc += part.bucket_of(h);
        }
        std::hint::black_box(acc);
    });
    println!(
        "skewed_hash_bucket: {:>8.1} ns/record",
        s.mean / 100_000.0 * 1e9
    );
}

fn bench_wordcount_sweep() {
    // The fig9-style sweep is the dominant bench cost: time one 64-task
    // wordcount sim end to end.
    use hemt::config::{ClusterConfig, WorkloadConfig};
    use hemt::coordinator::driver::SimParams;
    use hemt::coordinator::PartitionPolicy;
    let cluster = ClusterConfig::containers_1_and_04();
    let wl = WorkloadConfig::wordcount_2gb();
    let s = timed("wordcount_sim_64tasks", 1, 5, || {
        let mut sess = cluster.build_session(SimParams::default(), 1);
        let file = sess.hdfs.upload(wl.data_mb << 20, wl.block_mb << 20, &mut sess.rng);
        let job = hemt::workloads::wordcount_job(
            file,
            PartitionPolicy::EvenTasks(64),
            PartitionPolicy::EvenTasks(2),
            wl.cpu_secs_per_mb,
        );
        std::hint::black_box(sess.run_job(&job));
    });
    println!("wordcount_sim_64tasks: {} s", s.pm(6));
}

fn bench_pagerank_sweep() {
    // fig18's heaviest point: 100 iterations at 64-way — shuffle-heavy,
    // so it leans hardest on the network engine of any figure driver.
    use hemt::config::{ClusterConfig, PolicyConfig, WorkloadConfig};
    let cluster = ClusterConfig::containers_1_and_04();
    let wl = WorkloadConfig::pagerank_256mb();
    let s = timed("pagerank_sim_100it_64tasks", 0, 3, || {
        std::hint::black_box(hemt::experiments::pagerank_total_time(
            &cluster,
            &wl,
            &PolicyConfig::Homt(64),
            1,
        ));
    });
    println!("pagerank_sim_100it_64tasks: {} s", s.pm(4));
}

fn bench_sweep_parallelism() {
    // One figure-sized sweep spec, serial pool vs the machine's full
    // pool. Output is bit-identical; only wall-clock differs.
    use hemt::experiments::fig5_spec;
    use hemt::sweep::SweepRunner;
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let serial = timed("sweep_fig5_serial", 0, 3, || {
        std::hint::black_box(SweepRunner::new(1).run(&fig5_spec()));
    });
    let pooled = timed("sweep_fig5_pool", 0, 3, || {
        std::hint::black_box(SweepRunner::new(threads).run(&fig5_spec()));
    });
    println!(
        "sweep_fig5_serial:   {} s\nsweep_fig5_pool({threads}): {} s  ({:.2}x)",
        serial.pm(3),
        pooled.pm(3),
        serial.mean / pooled.mean
    );
}

fn main() {
    println!("== perf_microbench (L3 hot paths) ==");
    bench_engine_event_throughput();
    bench_netsim_coupled();
    bench_netsim_multirack();
    bench_partitioners();
    bench_wordcount_sweep();
    bench_pagerank_sweep();
    bench_sweep_parallelism();
}

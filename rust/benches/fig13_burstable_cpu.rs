//! Bench: regenerate the paper's "Fig 13 burstable CPU-bound" and time the experiment driver.
//! Run via `cargo bench --bench fig13_burstable_cpu`.
use hemt::bench_harness::run_figure_bench;
use hemt::experiments;

fn main() {
    run_figure_bench("fig13_burstable_cpu", 1, experiments::fig13);
}

//! Work-stealing comparison bench: the `hemt steal` four-arm figure
//! (Steal-HeMT vs Adaptive-HeMT vs static-HeMT vs HomT across the
//! capacity-program families) timed through the sweep runner, serial
//! baseline vs the machine's full pool.
//!
//! Writes `BENCH_steal_sweep.json` (pooled) and
//! `BENCH_steal_sweep_serial.json` for the CI trajectory gate. The
//! steal arm exercises the whole new path — the engine split primitive,
//! the capacity tap, and the stage-loop steal scans — so this bench is
//! the end-to-end wall-clock trajectory of the stealing subsystem.

use hemt::bench_harness::time_and_report;
use hemt::dynamics::{steal_comparison_spec, COMPARISON_BASE_SEED, COMPARISON_FAMILIES};
use hemt::sweep::{session_cache_stats, SweepRunner};

const ROUNDS: usize = 8;

fn main() {
    println!(
        "== steal_sweep: {} families x 4 policies x {ROUNDS} rounds ==",
        COMPARISON_FAMILIES.len()
    );
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let serial = time_and_report("steal_sweep_serial", 0, 3, || {
        std::hint::black_box(
            SweepRunner::new(1).run(&steal_comparison_spec(ROUNDS, COMPARISON_BASE_SEED)),
        );
    });
    let mut last = None;
    let pooled = time_and_report("steal_sweep", 0, 3, || {
        last = Some(
            SweepRunner::new(threads)
                .run(&steal_comparison_spec(ROUNDS, COMPARISON_BASE_SEED)),
        );
    });
    let (hits, misses) = session_cache_stats();
    println!(
        "steal_sweep_serial:    {} s\nsteal_sweep_pool({threads}): {} s  ({:.2}x)",
        serial.pm(3),
        pooled.pm(3),
        serial.mean / pooled.mean
    );
    println!("session cache: {hits} hits / {misses} misses");
    println!();
    println!("{}", last.expect("pooled run happened").to_table());
}

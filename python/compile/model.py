# L2: the jitted jax compute graphs the rust coordinator executes per task,
# each calling the L1 Pallas kernels.
#
# These are the *task bodies* of the paper's three workloads (WordCount,
# K-Means, PageRank). The rust side slices a task's data into fixed-shape
# blocks (padding with weight 0.0 / zero rows) and invokes the compiled
# artifact once per block, so one HLO shape per workload suffices.
#
# Shapes are frozen here — AOT artifacts are shape-specialized — and
# mirrored on the rust side in `rust/src/runtime/shapes.rs`.
import jax
import jax.numpy as jnp

from .kernels import histogram_pallas, kmeans_step_pallas, pagerank_block_pallas

# Frozen artifact shapes. Keep in sync with rust/src/runtime/shapes.rs.
WORDCOUNT_BLOCK_TOKENS = 65536
WORDCOUNT_BINS = 1024
KMEANS_BLOCK_POINTS = 4096
KMEANS_DIM = 32
KMEANS_K = 16
PAGERANK_N = 1024
PAGERANK_ROW_BLOCK = 256
PAGERANK_DAMPING = 0.85


def wordcount_map(tokens: jnp.ndarray, weights: jnp.ndarray):
    """WordCount map-task body: weighted token histogram over one block.

    tokens (65536,) int32, weights (65536,) f32 -> (counts (1024,) f32,)
    """
    return (histogram_pallas(tokens, weights, WORDCOUNT_BINS),)


def kmeans_step(points: jnp.ndarray, weights: jnp.ndarray,
                centroids: jnp.ndarray):
    """K-Means map-task body: per-cluster (sums, counts) over one block.

    points (4096, 32) f32, weights (4096,) f32, centroids (16, 32) f32
    -> (sums (16, 32) f32, counts (16,) f32)
    """
    return kmeans_step_pallas(points, weights, centroids)


def pagerank_step(p_block: jnp.ndarray, rank: jnp.ndarray):
    """PageRank task body: damped matvec for one row block.

    p_block (256, 1024) f32, rank (1024,) f32 -> (rank_block (256,) f32,)
    """
    return (pagerank_block_pallas(p_block, rank, PAGERANK_DAMPING),)


def lowerings():
    """(name, jitted fn, example args) for every AOT artifact."""
    f32 = jnp.float32
    i32 = jnp.int32
    return [
        (
            "wordcount",
            jax.jit(wordcount_map),
            (
                jax.ShapeDtypeStruct((WORDCOUNT_BLOCK_TOKENS,), i32),
                jax.ShapeDtypeStruct((WORDCOUNT_BLOCK_TOKENS,), f32),
            ),
        ),
        (
            "kmeans",
            jax.jit(kmeans_step),
            (
                jax.ShapeDtypeStruct((KMEANS_BLOCK_POINTS, KMEANS_DIM), f32),
                jax.ShapeDtypeStruct((KMEANS_BLOCK_POINTS,), f32),
                jax.ShapeDtypeStruct((KMEANS_K, KMEANS_DIM), f32),
            ),
        ),
        (
            "pagerank",
            jax.jit(pagerank_step),
            (
                jax.ShapeDtypeStruct((PAGERANK_ROW_BLOCK, PAGERANK_N), f32),
                jax.ShapeDtypeStruct((PAGERANK_N,), f32),
            ),
        ),
    ]

# AOT lowering: jax -> HLO *text* artifacts for the rust PJRT runtime.
#
# HLO text (not HloModuleProto.serialize()) is the interchange format: jax
# >= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
# (what the published `xla` 0.1.6 crate links) rejects with
# `proto.id() <= INT_MAX`; the text parser reassigns ids and round-trips
# cleanly. Lower with return_tuple=True and unwrap with to_tuple on the
# rust side. See /opt/xla-example/README.md.
#
# Runs once at build time (`make artifacts`); python is never on the rust
# request path.
import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text, with a tuple return."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts",
                        help="directory for <name>.hlo.txt artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, fn, example_args in model.lowerings():
        lowered = fn.lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(a.shape), "dtype": a.dtype.name}
                for a in example_args
            ],
            "chars": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()

# L1 Pallas kernel: one Lloyd (K-Means) accumulation step.
#
# The hot spot is the point-to-centroid distance computation. It is
# formulated as ||x-c||^2 = ||x||^2 - 2 x.c + ||c||^2 so the dominant cost
# is the (TILE, D) x (D, K) cross-term matmul — MXU-shaped on TPU — rather
# than an O(N*K*D) elementwise distance loop. The per-cluster sums are a
# second matmul (onehot.T @ X). Outputs accumulate across point tiles.
#
# TPU adaptation notes (DESIGN.md §Hardware-Adaptation):
#   * VMEM per step = TILE*D*4 (points) + K*D*4 (centroids, resident) +
#     TILE*K*4 (dist/onehot) + K*D*4 + K*4 (acc). TILE=1024, D=64, K=64
#     -> ~0.6 MB; room to scale TILE to 8192 before VMEM pressure.
#   * Both matmuls are bf16-able on real hardware; f32 here for exactness
#     against the oracle.
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kmeans_kernel(x_ref, w_ref, c_ref, sums_ref, counts_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    x = x_ref[...]                                        # (TILE, D)
    w = w_ref[...]                                        # (TILE,)
    c = c_ref[...]                                        # (K, D)
    cross = x @ c.T                                       # (TILE, K) — MXU
    cnorm = jnp.sum(c * c, axis=1)                        # (K,)
    dist = cnorm[None, :] - 2.0 * cross                   # + ||x||^2 const
    assign = jnp.argmin(dist, axis=1)                     # (TILE,)
    k = c.shape[0]
    ks = jax.lax.broadcasted_iota(jnp.int32, (x.shape[0], k), 1)
    onehot = (assign[:, None] == ks).astype(jnp.float32) * w[:, None]
    sums_ref[...] += onehot.T @ x                         # (K, D) — MXU
    counts_ref[...] += jnp.sum(onehot, axis=0)            # (K,)


def kmeans_step_pallas(points: jnp.ndarray, weights: jnp.ndarray,
                       centroids: jnp.ndarray, tile: int = 1024):
    """Per-cluster weighted (sums, counts) for one Lloyd step.

    points (N, D) with N a multiple of `tile`; weights (N,) zero on padding
    rows; centroids (K, D). Matches `ref.kmeans_step_ref`.
    """
    n, d = points.shape
    k, dc = centroids.shape
    assert d == dc, f"point dim {d} != centroid dim {dc}"
    assert n % tile == 0, f"point count {n} not a multiple of tile {tile}"
    grid = (n // tile,)
    return pl.pallas_call(
        _kmeans_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=True,
    )(points, weights, centroids)

# L1: Pallas kernels for the paper workloads' compute hot-spots.
#
# Every kernel runs with interpret=True (the CPU PJRT plugin cannot execute
# Mosaic custom-calls); TPU-shape reasoning lives in the per-kernel headers
# and DESIGN.md §Hardware-Adaptation.
from .histogram import histogram_pallas
from .kmeans import kmeans_step_pallas
from .pagerank import pagerank_block_pallas
from . import ref

__all__ = [
    "histogram_pallas",
    "kmeans_step_pallas",
    "pagerank_block_pallas",
    "ref",
]

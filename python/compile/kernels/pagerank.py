# L1 Pallas kernel: one damped PageRank power-iteration step for a row
# block — a blocked matvec with the teleport term fused in.
#
# Grid is (row_blocks, col_blocks); the column axis is the reduction axis,
# accumulated into the same output block across j steps (the standard
# Pallas revisiting pattern). Damping and the (1-d)/N teleport term are
# applied on the final reduction step so each output row leaves the kernel
# complete.
#
# TPU adaptation notes (DESIGN.md §Hardware-Adaptation):
#   * VMEM per step = BR*BC*4 (P tile) + BC*4 (rank slice) + BR*4 (acc).
#     BR=BC=256 -> ~260 KB; double-buffering the P tile stream is the
#     natural BlockSpec schedule (HBM->VMEM prefetch of tile (i, j+1)).
#   * The matvec maps to the MXU as a (BR,BC)x(BC,1) matmul.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pagerank_kernel(p_ref, r_ref, o_ref, *, damping: float, n: int,
                     col_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    p = p_ref[...]                                        # (BR, BC)
    r = r_ref[...]                                        # (BC,)
    o_ref[...] += (p @ r[:, None])[:, 0]                  # (BR,) — MXU

    @pl.when(j == col_blocks - 1)
    def _finish():
        o_ref[...] = damping * o_ref[...] + (1.0 - damping) / n


def pagerank_block_pallas(p_block: jnp.ndarray, rank: jnp.ndarray,
                          damping: float = 0.85, br: int = 256,
                          bc: int = 256) -> jnp.ndarray:
    """damping * p_block @ rank + (1-damping)/N via a blocked Pallas matvec.

    p_block (B, N) with B % br == 0 and N % bc == 0; rank (N,).
    Matches `ref.pagerank_block_ref`.
    """
    b, n = p_block.shape
    assert b % br == 0 and n % bc == 0, (b, n, br, bc)
    grid = (b // br, n // bc)
    return pl.pallas_call(
        functools.partial(_pagerank_kernel, damping=damping, n=n,
                          col_blocks=n // bc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((bc,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(p_block, rank)

# L1 Pallas kernel: weighted token histogram (the WordCount map hot-spot).
#
# The scatter-add a CPU WordCount would do is re-expressed as a one-hot
# matmul so the inner loop is MXU-shaped on TPU: for each token tile we
# build a (TILE, BINS) one-hot matrix and reduce it (weighted) over the
# tile axis, accumulating into the (BINS,) output across grid steps.
#
# TPU adaptation notes (DESIGN.md §Hardware-Adaptation):
#   * VMEM per step = TILE*4 (tokens) + TILE*4 (weights) + TILE*BINS*4
#     (one-hot scratch) + BINS*4 (acc). TILE=2048, BINS=1024 -> ~8.4 MB,
#     comfortably inside 16 MB VMEM.
#   * The one-hot reduce is `w @ onehot`, a (1,TILE)x(TILE,BINS) matmul.
# interpret=True is mandatory here: the CPU PJRT plugin cannot run Mosaic
# custom-calls, and interpret mode lowers to plain HLO.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _histogram_kernel(tok_ref, w_ref, o_ref, *, num_bins: int):
    """One grid step: accumulate the weighted one-hot of a token tile."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    tok = tok_ref[...]                                    # (TILE,) int32
    w = w_ref[...]                                        # (TILE,) f32
    bins = jax.lax.broadcasted_iota(jnp.int32, (tok.shape[0], num_bins), 1)
    onehot = (tok[:, None] == bins).astype(jnp.float32)   # (TILE, BINS)
    # (1,TILE) @ (TILE,BINS) -> (1,BINS): the MXU-shaped reduction.
    o_ref[...] += (w[None, :] @ onehot)[0]


def histogram_pallas(tokens: jnp.ndarray, weights: jnp.ndarray, num_bins: int,
                     tile: int = 2048) -> jnp.ndarray:
    """Weighted histogram of int32 token ids via a Pallas one-hot-matmul.

    tokens/weights are (T,) with T a multiple of `tile`. Padding tokens
    carry weight 0.0, so callers can pad freely. Matches
    `ref.histogram_ref` bit-for-bit shape-wise (f32 counts).
    """
    (t,) = tokens.shape
    assert t % tile == 0, f"token count {t} not a multiple of tile {tile}"
    grid = (t // tile,)
    return pl.pallas_call(
        functools.partial(_histogram_kernel, num_bins=num_bins),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((num_bins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_bins,), jnp.float32),
        interpret=True,
    )(tokens, weights)

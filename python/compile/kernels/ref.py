# Pure-jnp correctness oracles for the Pallas kernels.
#
# Each oracle is the straight-line jax.numpy definition of the computation
# the corresponding Pallas kernel implements. pytest (python/tests/) checks
# kernel-vs-oracle with assert_allclose across hypothesis-driven shape and
# dtype sweeps; these are the single source of numerical truth.
import jax.numpy as jnp


def histogram_ref(tokens: jnp.ndarray, weights: jnp.ndarray, num_bins: int) -> jnp.ndarray:
    """Weighted histogram of integer token ids.

    tokens:  (T,) int32 ids in [0, num_bins)
    weights: (T,) float32 per-token weight (0.0 for padding)
    returns: (num_bins,) float32 weighted counts
    """
    onehot = (tokens[:, None] == jnp.arange(num_bins)[None, :]).astype(jnp.float32)
    return (weights[:, None] * onehot).sum(axis=0)


def kmeans_step_ref(points: jnp.ndarray, weights: jnp.ndarray, centroids: jnp.ndarray):
    """One Lloyd accumulation step.

    points:    (N, D) float32
    weights:   (N,)   float32 (0.0 for padding rows)
    centroids: (K, D) float32
    returns: (sums (K, D), counts (K,)) — per-cluster weighted point sums
             and weighted member counts. New centroids are sums/counts
             (computed by the caller so zero-count clusters can be handled
             with the old centroid).
    """
    # ||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2 ; ||x||^2 is constant per row,
    # so the argmin only needs the cross term and ||c||^2.
    cross = points @ centroids.T                        # (N, K)
    cnorm = (centroids * centroids).sum(axis=1)          # (K,)
    dist = cnorm[None, :] - 2.0 * cross                  # (N, K) + const
    assign = jnp.argmin(dist, axis=1)                    # (N,)
    onehot = (assign[:, None] == jnp.arange(centroids.shape[0])[None, :])
    onehot = onehot.astype(jnp.float32) * weights[:, None]
    sums = onehot.T @ points                             # (K, D)
    counts = onehot.sum(axis=0)                          # (K,)
    return sums, counts


def pagerank_block_ref(p_block: jnp.ndarray, rank: jnp.ndarray, damping: float) -> jnp.ndarray:
    """One damped power-iteration step for a row block.

    p_block: (B, N) float32 — row slice of the column-stochastic matrix
    rank:    (N,)   float32 — current rank vector
    returns: (B,)   float32 — damping * p_block @ rank + (1-damping)/N
    """
    n = p_block.shape[1]
    return damping * (p_block @ rank) + (1.0 - damping) / n

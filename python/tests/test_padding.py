# Padding invariance: the rust runtime pads variable-length task slices
# into the frozen artifact shapes (zero weights / zero rows). These tests
# pin the contract: padded and unpadded inputs must agree exactly on the
# valid prefix, across hypothesis-driven valid lengths.
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


@settings(max_examples=10, deadline=None)
@given(
    valid=st.integers(min_value=1, max_value=model.WORDCOUNT_BLOCK_TOKENS),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_wordcount_padding_invariance(valid, seed):
    rng = np.random.default_rng(seed)
    t = model.WORDCOUNT_BLOCK_TOKENS
    tokens = np.zeros(t, dtype=np.int32)
    weights = np.zeros(t, dtype=np.float32)
    tokens[:valid] = rng.integers(0, model.WORDCOUNT_BINS, size=valid)
    weights[:valid] = 1.0
    (got,) = model.wordcount_map(jnp.asarray(tokens), jnp.asarray(weights))
    want = np.bincount(tokens[:valid], minlength=model.WORDCOUNT_BINS).astype(np.float32)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(
    valid=st.integers(min_value=1, max_value=model.KMEANS_BLOCK_POINTS),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kmeans_padding_invariance(valid, seed):
    rng = np.random.default_rng(seed)
    n, d, k = model.KMEANS_BLOCK_POINTS, model.KMEANS_DIM, model.KMEANS_K
    pts = np.zeros((n, d), dtype=np.float32)
    w = np.zeros(n, dtype=np.float32)
    pts[:valid] = rng.normal(size=(valid, d)).astype(np.float32)
    w[:valid] = 1.0
    c = rng.normal(size=(k, d)).astype(np.float32)
    got_s, got_c = model.kmeans_step(jnp.asarray(pts), jnp.asarray(w), jnp.asarray(c))
    # Oracle on the unpadded prefix only.
    want_s, want_c = ref.kmeans_step_ref(
        jnp.asarray(pts[:valid]),
        jnp.ones(valid, dtype=jnp.float32),
        jnp.asarray(c),
    )
    np.testing.assert_allclose(got_s, want_s, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(got_c, want_c, rtol=1e-4, atol=1e-4)


def test_pagerank_zero_rows_map_to_teleport():
    # A padded (all-zero) row block yields exactly the teleport term for
    # every padded row — the rust side slices those rows away.
    n, b = model.PAGERANK_N, model.PAGERANK_ROW_BLOCK
    p = jnp.zeros((b, n), dtype=jnp.float32)
    r = jnp.ones((n,), dtype=jnp.float32)
    (got,) = model.pagerank_step(p, r)
    np.testing.assert_allclose(
        np.asarray(got),
        np.full(b, (1.0 - model.PAGERANK_DAMPING) / n, dtype=np.float32),
        rtol=1e-6,
    )

# Kernel-vs-oracle correctness: the CORE numerical signal for L1.
#
# Every Pallas kernel is checked against its pure-jnp oracle in ref.py via
# assert_allclose, across hypothesis-driven shape/value sweeps plus pinned
# edge cases (all-padding, single-cluster, identity matrices).
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    histogram_pallas,
    kmeans_step_pallas,
    pagerank_block_pallas,
    ref,
)

RNG = np.random.default_rng


# ---------------------------------------------------------------- histogram

@settings(max_examples=20, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    tile=st.sampled_from([128, 256, 512]),
    bins=st.sampled_from([16, 64, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_histogram_matches_ref(tiles, tile, bins, seed):
    rng = RNG(seed)
    t = tiles * tile
    tokens = jnp.asarray(rng.integers(0, bins, size=t), dtype=jnp.int32)
    weights = jnp.asarray(rng.uniform(0.0, 2.0, size=t), dtype=jnp.float32)
    got = histogram_pallas(tokens, weights, bins, tile=tile)
    want = ref.histogram_ref(tokens, weights, bins)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_histogram_padding_is_ignored():
    tokens = jnp.zeros((256,), dtype=jnp.int32)  # all id 0
    weights = jnp.zeros((256,), dtype=jnp.float32)  # but all padding
    got = histogram_pallas(tokens, weights, 16, tile=128)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(16))


def test_histogram_unit_weights_count_exactly():
    rng = RNG(7)
    tokens_np = rng.integers(0, 32, size=512)
    tokens = jnp.asarray(tokens_np, dtype=jnp.int32)
    weights = jnp.ones((512,), dtype=jnp.float32)
    got = np.asarray(histogram_pallas(tokens, weights, 32, tile=128))
    want = np.bincount(tokens_np, minlength=32).astype(np.float32)
    np.testing.assert_array_equal(got, want)


def test_histogram_total_mass_conserved():
    rng = RNG(11)
    tokens = jnp.asarray(rng.integers(0, 64, size=1024), dtype=jnp.int32)
    weights = jnp.asarray(rng.uniform(size=1024), dtype=jnp.float32)
    got = histogram_pallas(tokens, weights, 64, tile=256)
    np.testing.assert_allclose(float(got.sum()), float(weights.sum()),
                               rtol=1e-5)


def test_histogram_rejects_misaligned_tile():
    tokens = jnp.zeros((100,), dtype=jnp.int32)
    weights = jnp.ones((100,), dtype=jnp.float32)
    with pytest.raises(AssertionError):
        histogram_pallas(tokens, weights, 16, tile=64)


# ------------------------------------------------------------------- kmeans

@settings(max_examples=15, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=3),
    tile=st.sampled_from([128, 256]),
    d=st.sampled_from([4, 16, 32]),
    k=st.sampled_from([2, 8, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kmeans_matches_ref(tiles, tile, d, k, seed):
    rng = RNG(seed)
    n = tiles * tile
    pts = jnp.asarray(rng.normal(size=(n, d)), dtype=jnp.float32)
    w = jnp.asarray(rng.integers(0, 2, size=n), dtype=jnp.float32)
    c = jnp.asarray(rng.normal(size=(k, d)), dtype=jnp.float32)
    got_s, got_c = kmeans_step_pallas(pts, w, c, tile=tile)
    want_s, want_c = ref.kmeans_step_ref(pts, w, c)
    np.testing.assert_allclose(got_s, want_s, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_c, want_c, rtol=1e-5, atol=1e-5)


def test_kmeans_counts_sum_to_weight_mass():
    rng = RNG(3)
    pts = jnp.asarray(rng.normal(size=(512, 8)), dtype=jnp.float32)
    w = jnp.asarray(rng.uniform(size=512), dtype=jnp.float32)
    c = jnp.asarray(rng.normal(size=(4, 8)), dtype=jnp.float32)
    _, counts = kmeans_step_pallas(pts, w, c, tile=128)
    np.testing.assert_allclose(float(counts.sum()), float(w.sum()), rtol=1e-5)


def test_kmeans_all_padding_yields_zero():
    pts = jnp.ones((256, 4), dtype=jnp.float32)
    w = jnp.zeros((256,), dtype=jnp.float32)
    c = jnp.zeros((2, 4), dtype=jnp.float32)
    sums, counts = kmeans_step_pallas(pts, w, c, tile=128)
    np.testing.assert_array_equal(np.asarray(sums), np.zeros((2, 4)))
    np.testing.assert_array_equal(np.asarray(counts), np.zeros(2))


def test_kmeans_single_cluster_takes_everything():
    rng = RNG(5)
    pts = jnp.asarray(rng.normal(size=(256, 4)), dtype=jnp.float32)
    w = jnp.ones((256,), dtype=jnp.float32)
    c = jnp.zeros((1, 4), dtype=jnp.float32)
    sums, counts = kmeans_step_pallas(pts, w, c, tile=128)
    np.testing.assert_allclose(np.asarray(sums)[0],
                               np.asarray(pts).sum(axis=0), rtol=1e-4)
    assert float(counts[0]) == 256.0


def test_kmeans_converges_on_separated_blobs():
    # Two well-separated blobs: one Lloyd step from rough centroids must
    # land each centroid on its blob mean.
    rng = RNG(13)
    a = rng.normal(loc=-10.0, size=(128, 8))
    b = rng.normal(loc=+10.0, size=(128, 8))
    pts = jnp.asarray(np.concatenate([a, b]), dtype=jnp.float32)
    w = jnp.ones((256,), dtype=jnp.float32)
    c = jnp.asarray([[-1.0] * 8, [1.0] * 8], dtype=jnp.float32)
    sums, counts = kmeans_step_pallas(pts, w, c, tile=128)
    new_c = np.asarray(sums) / np.asarray(counts)[:, None]
    np.testing.assert_allclose(new_c[0], a.mean(axis=0), atol=1e-3)
    np.testing.assert_allclose(new_c[1], b.mean(axis=0), atol=1e-3)


# ----------------------------------------------------------------- pagerank

@settings(max_examples=15, deadline=None)
@given(
    rb=st.sampled_from([64, 128]),
    rows=st.integers(min_value=1, max_value=3),
    cols=st.integers(min_value=1, max_value=3),
    damping=st.floats(min_value=0.5, max_value=0.95),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pagerank_matches_ref(rb, rows, cols, damping, seed):
    rng = RNG(seed)
    b, n = rows * rb, cols * rb
    p = jnp.asarray(rng.uniform(size=(b, n)), dtype=jnp.float32)
    r = jnp.asarray(rng.uniform(size=n), dtype=jnp.float32)
    got = pagerank_block_pallas(p, r, damping, br=rb, bc=rb)
    want = ref.pagerank_block_ref(p, r, damping)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_pagerank_stochastic_fixed_point():
    # Uniform rank is the fixed point of a doubly-stochastic square P.
    n = 256
    p = jnp.full((n, n), 1.0 / n, dtype=jnp.float32)
    r = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    got = pagerank_block_pallas(p, r, 0.85, br=128, bc=128)
    np.testing.assert_allclose(np.asarray(got), np.full(n, 1.0 / n),
                               rtol=1e-4)


def test_pagerank_zero_matrix_gives_teleport_only():
    n = 128
    p = jnp.zeros((n, n), dtype=jnp.float32)
    r = jnp.ones((n,), dtype=jnp.float32)
    got = pagerank_block_pallas(p, r, 0.85, br=64, bc=64)
    np.testing.assert_allclose(np.asarray(got), np.full(n, 0.15 / n),
                               rtol=1e-5)


def test_pagerank_rank_mass_conserved_over_iterations():
    # With a column-stochastic P, total rank mass stays 1 under iteration.
    rng = RNG(17)
    n = 256
    raw = rng.uniform(size=(n, n)).astype(np.float32)
    p = jnp.asarray(raw / raw.sum(axis=0, keepdims=True))
    r = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    for _ in range(5):
        r = pagerank_block_pallas(p, r, 0.85, br=128, bc=128)
    np.testing.assert_allclose(float(r.sum()), 1.0, rtol=1e-4)

# L2 model shape checks + AOT lowering smoke tests.
#
# Verifies that every artifact in model.lowerings() lowers to HLO text that
# (a) parses as non-trivial HLO, (b) matches the frozen shapes mirrored in
# rust/src/runtime/shapes.rs, and (c) computes the same numbers as the
# eager path when re-imported through the XLA client.
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.aot import to_hlo_text
from compile.kernels import ref


def test_lowerings_inventory():
    names = [name for name, _, _ in model.lowerings()]
    assert names == ["wordcount", "kmeans", "pagerank"]


@pytest.mark.parametrize("name,fn,args", model.lowerings(),
                         ids=[n for n, _, _ in model.lowerings()])
def test_artifact_lowers_to_hlo_text(name, fn, args):
    text = to_hlo_text(fn.lower(*args))
    assert f"HloModule" in text
    # Tuple-rooted entry so rust's to_tuple unwrap works.
    assert "ROOT" in text
    assert len(text) > 500, "suspiciously small HLO — lowering degenerated?"


def test_wordcount_model_matches_ref():
    rng = np.random.default_rng(0)
    t = model.WORDCOUNT_BLOCK_TOKENS
    tokens = jnp.asarray(rng.integers(0, model.WORDCOUNT_BINS, size=t),
                         dtype=jnp.int32)
    weights = jnp.asarray(rng.integers(0, 2, size=t), dtype=jnp.float32)
    (got,) = model.wordcount_map(tokens, weights)
    want = ref.histogram_ref(tokens, weights, model.WORDCOUNT_BINS)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_kmeans_model_matches_ref():
    rng = np.random.default_rng(1)
    pts = jnp.asarray(
        rng.normal(size=(model.KMEANS_BLOCK_POINTS, model.KMEANS_DIM)),
        dtype=jnp.float32)
    w = jnp.asarray(rng.integers(0, 2, size=model.KMEANS_BLOCK_POINTS),
                    dtype=jnp.float32)
    c = jnp.asarray(rng.normal(size=(model.KMEANS_K, model.KMEANS_DIM)),
                    dtype=jnp.float32)
    got_s, got_c = model.kmeans_step(pts, w, c)
    want_s, want_c = ref.kmeans_step_ref(pts, w, c)
    np.testing.assert_allclose(got_s, want_s, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(got_c, want_c, rtol=1e-5, atol=1e-4)


def test_pagerank_model_matches_ref():
    rng = np.random.default_rng(2)
    p = jnp.asarray(
        rng.uniform(size=(model.PAGERANK_ROW_BLOCK, model.PAGERANK_N)),
        dtype=jnp.float32)
    r = jnp.asarray(rng.uniform(size=model.PAGERANK_N), dtype=jnp.float32)
    (got,) = model.pagerank_step(p, r)
    want = ref.pagerank_block_ref(p, r, model.PAGERANK_DAMPING)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_hlo_text_roundtrips_through_xla_client():
    # Re-import the lowered wordcount HLO through the XLA client and check
    # numerics — the same path the rust runtime takes.
    from jax._src.lib import xla_client as xc
    name, fn, args = model.lowerings()[0]
    text = to_hlo_text(fn.lower(*args))
    # Parse back: if the text is malformed, this raises.
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None

# Make the `compile` package importable when pytest is invoked from the
# repository root (`python -m pytest python/tests -q`, the CI command):
# test modules import `compile.kernels`, which lives next to this file.
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
